"""Crash recovery + the durable index lifecycle entry points.

``recover(root)`` = newest valid checkpoint + replay of the valid WAL
suffix (records with LSN beyond the checkpoint), truncating torn tails
cleanly; mid-log corruption raises instead of yielding a silently shorter
history.  ``open_durable(root)`` wraps it into the full lifecycle: create
or recover the index and attach a live ``WalWriter`` so every subsequent
mutation is logged-then-applied.  ``load_serving_snapshot(root)`` is the
serve-from-checkpoint cold start: CRC-validated ``np.load(mmap_mode="r")``
slabs wrapped directly into a serving ``Snapshot`` — no graph replay, no
host index, first query served before the slabs are fully paged in.
"""
from __future__ import annotations

import logging
import os

from . import checkpoint, wal
from .faultfs import OsIO
from .format import CorruptError

log = logging.getLogger("repro.persist")

WAL_SUBDIR = "wal"


def wal_dir(root: str) -> str:
    return os.path.join(root, WAL_SUBDIR)


def is_durable_dir(root: str) -> bool:
    """True when ``root`` holds an index lifecycle (any checkpoint)."""
    return bool(checkpoint.list_checkpoints(root))


def recover(root: str, io: OsIO | None = None, upto_lsn: int | None = None):
    """Restore the newest recoverable index state: newest valid checkpoint
    chain, then replay every WAL record with ``lsn > checkpoint.lsn``.

    ``upto_lsn`` stops replay at that LSN (inclusive) — the replication
    tests use it to compare a fenced primary's disk state against a
    promoted replica *at the promotion LSN*, where the two must be
    bitwise-equal even though the primary's log carries unacked records
    beyond it.  Requires ``upto_lsn >= checkpoint.lsn`` (a checkpoint
    cannot be un-applied).

    Returns the recovered `WoWIndex` (no WAL attached — use
    ``open_durable`` to continue mutating durably).  Raises
    ``CorruptError``/``WalCorruptError`` when neither a valid checkpoint
    exists nor the log validates — a clean refusal, never a corrupt index.
    """
    io = io or OsIO()
    index = checkpoint.materialize(checkpoint.load_state(root))
    records = wal.read_log(wal_dir(root), io=io)
    base_lsn = index._applied_lsn
    if upto_lsn is not None and upto_lsn < base_lsn:
        raise ValueError(
            f"upto_lsn {upto_lsn} precedes the newest checkpoint "
            f"(lsn {base_lsn}); checkpoints cannot be un-applied"
        )
    pending = [(l, t, p) for l, t, p in records
               if l > base_lsn and (upto_lsn is None or l <= upto_lsn)]
    if pending and pending[0][0] != base_lsn + 1:
        raise wal.WalCorruptError(
            f"WAL starts at LSN {pending[0][0]} but checkpoint covers "
            f"through {base_lsn}: log has a gap"
        )
    index._wal_replaying = True
    try:
        for lsn, rtype, payload in pending:
            wal.apply_record(index, rtype, payload)
            index._applied_lsn = lsn
    finally:
        index._wal_replaying = False
    # the fencing epoch rides both the checkpoint manifest and the WAL
    # segment headers (a promotion rotates the log without checkpointing,
    # so the log can be ahead of the manifest — never behind)
    seg_epoch = wal.log_epoch(wal_dir(root))
    if seg_epoch > index._epoch:
        index._epoch = seg_epoch
    if pending:
        log.info("recovered %s: checkpoint lsn %d + %d WAL records",
                 root, base_lsn, len(pending))
    return index


def open_durable(root: str, io: OsIO | None = None, create: dict | None = None,
                 compact_threshold: float | None = None,
                 segment_bytes: int = 4 << 20):
    """Open (or create) a durable index at ``root`` and attach its WAL.

    Existing lifecycle: ``recover`` then append to the log.  Fresh
    directory: ``create`` must hold `WoWIndex` constructor kwargs (at least
    ``dim``); an empty *initial checkpoint* is written immediately so the
    index parameters are durable before the first WAL record.
    """
    io = io or OsIO()
    if is_durable_dir(root):
        index = recover(root, io=io)
    else:
        if create is None:
            raise ValueError(
                f"{root} holds no index; pass create={{'dim': ...}} to "
                f"initialize one"
            )
        from ..core.index import WoWIndex

        index = WoWIndex(**create)
        checkpoint.save(index, root, io=io)
    if compact_threshold is not None:
        index.compact_threshold = compact_threshold
    index._wal = wal.WalWriter(wal_dir(root), io=io,
                               segment_bytes=segment_bytes,
                               epoch=index._epoch)
    # a torn tail was truncated by recover(); the writer continues from
    # the last valid record, which must line up with what we replayed
    if index._wal.next_lsn != index._applied_lsn + 1:
        raise wal.WalCorruptError(
            f"WAL writer resumes at LSN {index._wal.next_lsn} but the "
            f"recovered index applied through {index._applied_lsn}"
        )
    return index


def load_serving_snapshot(root: str):
    """Serve-from-checkpoint cold start: build a serving ``Snapshot``
    straight from the newest valid checkpoint's slabs.

    Full checkpoints are memory mapped (``np.load(mmap_mode="r")`` after
    CRC validation), so the first query runs before the vector/adjacency
    slabs are fully paged in; delta chains compose in memory.  The
    snapshot reflects the *checkpoint* — WAL records past it need a full
    ``recover()`` (the serving engine does that lazily on first mutation).

    Returns ``(snapshot, meta)``.
    """
    from ..core.snapshot import snapshot_from_arrays

    state = checkpoint.load_state(root, mmap=True)
    meta = state["meta"]
    if meta["n"] == 0:
        raise CorruptError("cannot serve from an empty checkpoint")
    snap = snapshot_from_arrays(
        vectors=state["vectors"],
        sq_norms=state["sq_norms"],
        attrs=state["attrs"],
        neighbors=state["neighbors"],
        deleted=state["deleted"],
        m=meta["m"],
        o=meta["o"],
        metric=meta["metric"],
        stamp=meta["mutations"],
        # format-v2 quantized slabs (when the writer ran vec_dtype != f32):
        # the device upload reuses them directly, skipping re-quantization
        q_vectors=state.get("q_vectors"),
        q_scales=state.get("q_scales"),
        vec_dtype=meta.get("vec_dtype", "f32"),
    )
    return snap, meta

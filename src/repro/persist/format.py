"""On-disk primitives for the durable index lifecycle.

A checkpoint is a *directory*: a versioned ``MANIFEST.json`` header plus one
standalone ``.npy`` file per array section.  The manifest's section table
carries each section's byte length, CRC32, dtype and shape — so sections are
length-prefixed and checksummed exactly as a packed single-file format would
be, while keeping every array a plain ``.npy`` that ``np.load(mmap_mode="r")``
can map without copying (the serve-from-checkpoint cold start).  The manifest
itself is covered by a ``header_crc32`` over its canonical-JSON encoding.

All writes go through a ``faultfs`` io object so the fault-injection harness
can kill the writer at any byte offset.  Readers validate CRCs before any
byte reaches index state; validation failures raise ``CorruptError`` (a clean
refusal — never a silently corrupt index).

See ``PERSISTENCE.md`` for the full format specification.
"""
from __future__ import annotations

import io as _io
import json
import os
import zlib

import numpy as np

from .faultfs import OsIO

FORMAT_MAGIC = "WOWCKPT"
#: current writer version.  v2 added quantized vector sections
#: (``q_vectors``/``q_scales`` + ``vec_dtype`` meta) and switched the
#: ``dead_vals`` section to f32 (attrs are f32-canonical at ingest, so f32
#: is lossless; v1 checkpoints wrote f64 and are migrated on read).
FORMAT_VERSION = 2
#: versions this reader accepts.  Old checkpoints stay readable — version
#: bumps are for *new sections/semantics*, never a re-encode of old ones.
SUPPORTED_VERSIONS = (1, 2)
MANIFEST_NAME = "MANIFEST.json"


class CorruptError(Exception):
    """A checkpoint or WAL artifact failed validation (CRC/structure)."""


# ----------------------------------------------------------------- npy codec
def encode_npy(arr: np.ndarray) -> bytes:
    """Serialize an array to ``.npy`` bytes (format 1.0, no pickle)."""
    buf = _io.BytesIO()
    np.save(buf, np.ascontiguousarray(arr), allow_pickle=False)
    return buf.getvalue()


def decode_npy(data: bytes) -> np.ndarray:
    return np.load(_io.BytesIO(data), allow_pickle=False)


def canonical_json(obj) -> bytes:
    """Deterministic JSON encoding (sorted keys, no whitespace) — the byte
    string both the writer and the reader compute ``header_crc32`` over."""
    return json.dumps(obj, sort_keys=True, separators=(",", ":")).encode()


def crc32(data: bytes) -> int:
    return zlib.crc32(data) & 0xFFFFFFFF


# ------------------------------------------------------------ section writer
STREAM_CHUNK_BYTES = 256 << 10  # replication bootstrap streaming granularity


def chunk_crcs(data: bytes, chunk_bytes: int = STREAM_CHUNK_BYTES) -> list[int]:
    """Per-chunk CRC32 table over ``data`` split into ``chunk_bytes`` runs.
    Replica bootstrap streams sections chunk-at-a-time and, after a
    dropped transport or a crash, resumes by re-requesting only the
    chunks whose bytes on disk fail this table — never the full copy."""
    out = [crc32(data[off:off + chunk_bytes])
           for off in range(0, len(data), chunk_bytes)]
    return out or [crc32(b"")]


def write_section(io: OsIO, dirpath: str, name: str, arr: np.ndarray,
                  chunk_bytes: int = STREAM_CHUNK_BYTES) -> dict:
    """Write one array section as ``<name>.npy``; return its table entry."""
    data = encode_npy(arr)
    fname = f"{name}.npy"
    f = io.create(os.path.join(dirpath, fname))
    try:
        io.write(f, data)
        io.fsync(f)
    finally:
        io.close(f)
    return {
        "file": fname,
        "nbytes": len(data),
        "crc32": crc32(data),
        "dtype": str(arr.dtype),
        "shape": list(arr.shape),
        "chunk_bytes": chunk_bytes,
        "chunk_crcs": chunk_crcs(data, chunk_bytes),
    }


def read_section(dirpath: str, name: str, entry: dict,
                 mmap: bool = False) -> np.ndarray:
    """Read + validate one section.  With ``mmap=True`` the array is memory
    mapped (validation reads the file once through the page cache; the
    returned array then serves lazily from the mapping)."""
    path = os.path.join(dirpath, entry["file"])
    try:
        with open(path, "rb") as f:
            data = f.read()
    except OSError as e:
        raise CorruptError(f"section {name!r}: unreadable ({e})") from e
    if len(data) != entry["nbytes"]:
        raise CorruptError(
            f"section {name!r}: {len(data)} bytes on disk, manifest says "
            f"{entry['nbytes']}"
        )
    if crc32(data) != entry["crc32"]:
        raise CorruptError(f"section {name!r}: CRC32 mismatch")
    if mmap:
        arr = np.load(path, mmap_mode="r", allow_pickle=False)
    else:
        arr = decode_npy(data)
    if str(arr.dtype) != entry["dtype"] or list(arr.shape) != entry["shape"]:
        raise CorruptError(
            f"section {name!r}: dtype/shape {arr.dtype}/{arr.shape} does not "
            f"match manifest {entry['dtype']}/{entry['shape']}"
        )
    return arr


# ---------------------------------------------------------------- manifest
def write_manifest(io: OsIO, dirpath: str, manifest: dict) -> None:
    """Finalize the manifest: stamp magic/version, append ``header_crc32``
    over the canonical encoding, write + fsync."""
    manifest = dict(manifest)
    manifest["magic"] = FORMAT_MAGIC
    manifest["format_version"] = FORMAT_VERSION
    manifest.pop("header_crc32", None)
    manifest["header_crc32"] = crc32(canonical_json(manifest))
    f = io.create(os.path.join(dirpath, MANIFEST_NAME))
    try:
        io.write(f, json.dumps(manifest, sort_keys=True, indent=1).encode())
        io.fsync(f)
    finally:
        io.close(f)


def read_manifest(dirpath: str) -> dict:
    """Load + validate a checkpoint manifest (magic, version, header CRC)."""
    path = os.path.join(dirpath, MANIFEST_NAME)
    try:
        with open(path, "rb") as f:
            manifest = json.loads(f.read())
    except (OSError, ValueError) as e:
        raise CorruptError(f"manifest unreadable: {e}") from e
    if not isinstance(manifest, dict) or manifest.get("magic") != FORMAT_MAGIC:
        raise CorruptError("bad manifest magic")
    if manifest.get("format_version") not in SUPPORTED_VERSIONS:
        raise CorruptError(
            f"unsupported checkpoint format version "
            f"{manifest.get('format_version')!r} (reader supports "
            f"{SUPPORTED_VERSIONS})"
        )
    stated = manifest.get("header_crc32")
    body = {k: v for k, v in manifest.items() if k != "header_crc32"}
    if crc32(canonical_json(body)) != stated:
        raise CorruptError("manifest header CRC32 mismatch")
    return manifest

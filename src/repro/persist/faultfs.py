"""Injectable filesystem shim + fault-injection harness for the durable
index lifecycle.

Every byte the persistence subsystem writes (WAL appends, checkpoint
sections, atomic renames, directory fsyncs) goes through an ``OsIO``
instance, so a test can swap in a ``FaultIO`` and kill the writer at any
byte offset, drop fsyncs, or crash at an arbitrary operation — then assert
that recovery reaches a bitwise-identical prefix state.  Reads go through
the io too where recovery mutates state (torn-tail truncation).

Crash models
------------

A real crash leaves the filesystem somewhere between two extremes, both of
which ``FaultIO`` can materialize:

* ``model="flushed"`` (default) — every byte written before the crash
  reached disk, including a torn suffix of the final partial write.  This
  is the adversarial model for *torn records*: the crash offset lands
  mid-record and recovery must detect the torn tail via checksums.
* ``model="lost"`` — nothing past the last ``fsync`` survives: files roll
  back to their last-synced length, un-fsync'd creations disappear, and
  renames whose parent directory was never fsynced are undone.  This is
  the adversarial model for *dropped fsyncs* (``drop_fsync=True`` makes
  every fsync a silent no-op, so a later crash loses everything since the
  last durable point).

POSIX is messier than either model (sector-granularity tearing,
reordering), but any state a real crash can produce lies between these
two, and recovery is gated against both plus explicit bit flips
(``flip_bit``) and truncations (``truncate_at``).
"""
from __future__ import annotations

import os
import shutil


class CrashError(Exception):
    """Raised by ``FaultIO`` at the injected crash point."""


class OsIO:
    """Thin passthrough to the real filesystem.

    Handles returned by ``create``/``open_append`` are plain binary file
    objects; all mutating operations are methods so a fault-injection
    subclass can interpose on every one of them.
    """

    def mkdir(self, path: str) -> None:
        os.makedirs(path, exist_ok=True)

    def create(self, path: str):
        return open(path, "wb")

    def open_append(self, path: str):
        return open(path, "ab")

    def write(self, f, data: bytes) -> None:
        f.write(data)

    def fsync(self, f) -> None:
        f.flush()
        os.fsync(f.fileno())

    def close(self, f) -> None:
        f.close()

    def replace(self, src: str, dst: str) -> None:
        os.replace(src, dst)

    def fsync_dir(self, path: str) -> None:
        fd = os.open(path, os.O_RDONLY)
        try:
            os.fsync(fd)
        finally:
            os.close(fd)

    def remove(self, path: str) -> None:
        if os.path.isdir(path):
            shutil.rmtree(path)
        elif os.path.exists(path):
            os.remove(path)

    def truncate(self, path: str, length: int) -> None:
        with open(path, "r+b") as f:
            f.truncate(length)


class FaultIO(OsIO):
    """``OsIO`` with an injected crash point and fsync semantics.

    Parameters
    ----------
    crash_after_bytes:
        Raise ``CrashError`` once this many payload bytes have been
        written across all files; the final write is applied *partially*
        up to the crash byte (the kill-at-any-byte-offset capability).
    crash_after_ops:
        Raise after this many mutating operations (writes, fsyncs,
        renames, removals, creates) — for sweeping crash points through a
        checkpoint save, whose structure is op- rather than byte-shaped.
    drop_fsync:
        Make every file fsync a silent no-op (the written bytes stay in
        the "page cache" and are lost at a later ``model="lost"`` crash).
    model:
        What survives the crash — see the module docstring.

    ``ops`` counts mutating operations so a sweep can run once with no
    crash point to learn the op count, then re-run with
    ``crash_after_ops=k`` for every ``k``.
    """

    def __init__(
        self,
        crash_after_bytes: int | None = None,
        crash_after_ops: int | None = None,
        drop_fsync: bool = False,
        model: str = "flushed",
    ):
        if model not in ("flushed", "lost"):
            raise ValueError(f"unknown crash model {model!r}")
        self.crash_after_bytes = crash_after_bytes
        self.crash_after_ops = crash_after_ops
        self.drop_fsync = drop_fsync
        self.model = model
        self.bytes_written = 0
        self.ops = 0
        # durability tracking for the "lost" model
        self._synced_len: dict[str, int] = {}  # path -> length at last fsync
        self._pending_create: set[str] = set()  # created, parent not fsynced
        self._pending_replace: list[tuple[str, str]] = []  # (src, dst)
        self._lens: dict[str, int] = {}  # current (written) length per path

    # ------------------------------------------------------------- crash core
    def _tick(self) -> None:
        self.ops += 1
        if self.crash_after_ops is not None and self.ops > self.crash_after_ops:
            self._crash()

    def _crash(self) -> None:
        if self.model == "lost":
            self._rollback_to_durable()
        raise CrashError(
            f"injected crash (ops={self.ops}, bytes={self.bytes_written}, "
            f"model={self.model})"
        )

    def _rollback_to_durable(self) -> None:
        """Materialize the conservative post-crash state: un-synced bytes,
        creations and renames vanish."""
        for src, dst in reversed(self._pending_replace):
            if os.path.exists(dst):
                os.replace(dst, src)
        self._pending_replace.clear()
        for path in list(self._pending_create):
            if os.path.exists(path):
                OsIO.remove(self, path)
        self._pending_create.clear()
        for path, length in self._synced_len.items():
            if os.path.exists(path) and os.path.getsize(path) > length:
                with open(path, "r+b") as f:
                    f.truncate(length)

    # --------------------------------------------------------------- mutators
    def mkdir(self, path: str) -> None:
        self._tick()
        existed = os.path.isdir(path)
        super().mkdir(path)
        if not existed:
            self._pending_create.add(path)

    def create(self, path: str):
        self._tick()
        f = super().create(path)
        self._pending_create.add(path)
        self._synced_len[path] = 0
        self._lens[path] = 0
        return f

    def open_append(self, path: str):
        self._tick()
        f = super().open_append(path)
        size = os.path.getsize(path)
        self._synced_len.setdefault(path, size)
        self._lens[path] = size
        return f

    def write(self, f, data: bytes) -> None:
        self._tick()
        path = f.name
        if self.crash_after_bytes is not None:
            room = self.crash_after_bytes - self.bytes_written
            if room < len(data):
                # apply the surviving prefix of the torn write, then die
                if room > 0:
                    f.write(data[:room])
                    f.flush()
                    self.bytes_written += room
                    self._lens[path] = self._lens.get(path, 0) + room
                self._crash()
        f.write(data)
        self.bytes_written += len(data)
        self._lens[path] = self._lens.get(path, 0) + len(data)

    def fsync(self, f) -> None:
        self._tick()
        if self.drop_fsync:
            f.flush()  # reaches the "page cache" only
            return
        super().fsync(f)
        self._synced_len[f.name] = self._lens.get(f.name, 0)

    def replace(self, src: str, dst: str) -> None:
        self._tick()
        super().replace(src, dst)
        self._pending_replace.append((src, dst))
        if src in self._pending_create:
            self._pending_create.discard(src)
            self._pending_create.add(dst)
        for p in (src, dst):
            pass  # lengths keyed by path are only used for files, not dirs

    def fsync_dir(self, path: str) -> None:
        self._tick()
        if self.drop_fsync:
            return
        super().fsync_dir(path)
        norm = os.path.abspath(path)
        # everything directly under (or renamed into) this directory is now
        # durable
        self._pending_replace = [
            (s, d)
            for s, d in self._pending_replace
            if os.path.abspath(os.path.dirname(d)) != norm
        ]
        for p in list(self._pending_create):
            if os.path.abspath(os.path.dirname(p)) == norm:
                self._pending_create.discard(p)

    def remove(self, path: str) -> None:
        self._tick()
        super().remove(path)
        self._pending_create.discard(path)
        self._synced_len.pop(path, None)
        self._lens.pop(path, None)

    def truncate(self, path: str, length: int) -> None:
        self._tick()
        super().truncate(path, length)
        self._lens[path] = length
        if self._synced_len.get(path, 0) > length:
            self._synced_len[path] = length


# ----------------------------------------------------- engine-level fault plan
class EngineFaultPlan:
    """Fault plan for the serve engine's request lifecycle
    (``repro.serve.lifecycle.ServeEngine(fault_plan=...)``) — the
    scheduler-level complement of ``FaultIO``'s byte/op-level injection.

    The engine calls ``on_chunk`` before every executed hop chunk and
    ``on_ingest_apply`` before every ingest micro-batch apply; the plan
    either delays (injected slow waves — via ``sleep``, which a
    deterministic test points at its virtual clock's ``advance``) or
    raises ``CrashError`` at a configured point (simulated process death
    between the WAL ack and the apply — the window the WAL-backed ingest
    queue must survive).  Byte-level faults (torn WAL appends, dropped
    fsyncs) compose by also handing the engine's index a ``FaultIO``;
    SIGKILL-grade crashes are exercised by the subprocess tests, which
    this plan cannot (and should not) emulate in-process.

    Parameters
    ----------
    slow_chunk_every:
        Delay every Nth executed chunk (0 = never) by ``slow_chunk_s``.
    slow_chunk_s:
        The injected delay in seconds, applied through ``sleep``.
    crash_after_chunks:
        Raise ``CrashError`` once this many chunks have executed.
    crash_after_ingest_applies:
        Raise ``CrashError`` once this many ingest micro-batches have
        been applied — the mid-ingest-queue crash point: earlier batches
        are applied, later ones are logged-and-acked but pending.
    sleep:
        Delay implementation (default ``time.sleep``); tests substitute a
        virtual clock's ``advance`` for deterministic deadline storms.
    """

    def __init__(
        self,
        slow_chunk_every: int = 0,
        slow_chunk_s: float = 0.0,
        crash_after_chunks: int | None = None,
        crash_after_ingest_applies: int | None = None,
        sleep=None,
    ):
        import time

        self.slow_chunk_every = int(slow_chunk_every)
        self.slow_chunk_s = float(slow_chunk_s)
        self.crash_after_chunks = crash_after_chunks
        self.crash_after_ingest_applies = crash_after_ingest_applies
        self.sleep = sleep if sleep is not None else time.sleep
        self.chunks = 0
        self.ingest_applies = 0

    def on_chunk(self) -> None:
        self.chunks += 1
        if (
            self.crash_after_chunks is not None
            and self.chunks > self.crash_after_chunks
        ):
            raise CrashError(
                f"injected engine crash (chunk {self.chunks})"
            )
        if self.slow_chunk_every and self.chunks % self.slow_chunk_every == 0:
            self.sleep(self.slow_chunk_s)

    def on_ingest_apply(self) -> None:
        self.ingest_applies += 1
        if (
            self.crash_after_ingest_applies is not None
            and self.ingest_applies > self.crash_after_ingest_applies
        ):
            raise CrashError(
                f"injected engine crash (ingest apply {self.ingest_applies})"
            )


# --------------------------------------------------------------- test helpers
def flip_bit(path: str, byte_index: int, bit: int = 0) -> None:
    """Flip one bit of a file in place (corruption injection)."""
    with open(path, "r+b") as f:
        f.seek(byte_index)
        b = f.read(1)
        f.seek(byte_index)
        f.write(bytes([b[0] ^ (1 << bit)]))


def truncate_at(path: str, length: int) -> None:
    """Truncate a file to ``length`` bytes (torn-write injection)."""
    with open(path, "r+b") as f:
        f.truncate(length)

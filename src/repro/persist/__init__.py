"""Durable index lifecycle: versioned checkpoints, append-only WAL, crash
recovery, serve-from-checkpoint cold start, and a fault-injection harness.

See ``PERSISTENCE.md`` for the on-disk format specification, recovery
semantics and the durability guarantees table.
"""
from .checkpoint import (  # noqa: F401
    assert_index_equal,
    list_checkpoints,
    load,
    save,
    state_digest,
)
from .faultfs import (  # noqa: F401
    CrashError,
    EngineFaultPlan,
    FaultIO,
    OsIO,
    flip_bit,
    truncate_at,
)
from .format import STREAM_CHUNK_BYTES, CorruptError, chunk_crcs  # noqa: F401
from .recovery import (  # noqa: F401
    is_durable_dir,
    load_serving_snapshot,
    open_durable,
    recover,
    wal_dir,
)
from .replicate import (  # noqa: F401
    FaultSchedule,
    FaultTransport,
    InProcEndpoint,
    InProcTransport,
    PrimaryReplicator,
    QuorumTimeoutError,
    ReplicaReplicator,
    ReplicatedWal,
    SocketEndpoint,
)
from .wal import (  # noqa: F401
    StaleEpochError,
    WalCorruptError,
    WalWriter,
    log_epoch,
)

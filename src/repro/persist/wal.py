"""Append-only write-ahead log for `WoWIndex` mutations.

Every durable mutation — an ``insert_batch`` micro-batch, a sequential
``insert``, ``delete``/``undelete``, a (manual or auto-triggered)
``compact_rows`` pass — appends one self-checksummed record *before* the
in-memory apply, and the record is fsynced before the mutating call
returns.  Recovery (`repro.persist.recovery`) = newest valid checkpoint +
replay of the WAL suffix; replaying a record re-executes the original
index operation, and because every registered build backend commits a
bitwise-identical graph (the cross-backend equivalence gate) and the
index's RNG state rides in the checkpoint, replay reproduces the live
index bit for bit.

On-disk layout (all integers little-endian):

segment file ``wal-<seq:08d>.seg``::

    header (36 bytes):
      magic      8s   b"WOWWAL01"
      version    u32  1
      epoch      u32  fencing epoch/term (0 before replication existed;
                      the field was reserved-zero in v1 logs, so old
                      segments parse as epoch 0)
      seq        u64  segment sequence number
      start_lsn  u64  LSN of the segment's first record
      crc32      u32  over the preceding 32 bytes
    records, back to back::
      length     u32  len(body)
      crc32      u32  over body
      body:
        type     u8   record type (below)
        lsn      u64  log sequence number (monotone, gap-free)
        payload  type-specific (below)

Record types::

    1 INSERT      one insert_batch micro-batch:
                  u32 json_len + canonical JSON {backend, device_width,
                  shards} + .npy vectors (f32[B,d]) + .npy attrs (f64[B])
    2 DELETE      canonical JSON {vid}
    3 UNDELETE    canonical JSON {vid}
    4 COMPACT     empty (compact_rows is deterministic given index state)
    5 SEQ_INSERT  .npy vector (f32[d]) + .npy attr (f64[1])

Torn tails vs corruption: a crash can only tear the *tail* of the *last*
segment (records are appended then fsynced, and a new segment is created
only after its predecessor's records were all acked).  So an invalid
record is (a) a torn tail — iff it is in the last segment and no valid
record exists at any later byte offset — which recovery truncates away
cleanly, or (b) corruption (bit rot, manual tampering), which raises
``WalCorruptError``: a clean refusal, never a silently shortened log.
"""
from __future__ import annotations

import io as _io
import json
import os
import struct

import numpy as np

from .faultfs import OsIO
from .format import CorruptError, canonical_json, crc32, encode_npy

SEG_MAGIC = b"WOWWAL01"
SEG_VERSION = 1
SEG_HEADER_LEN = 36
REC_OVERHEAD = 8  # u32 length + u32 crc
MIN_BODY = 9  # u8 type + u64 lsn

T_INSERT = 1
T_DELETE = 2
T_UNDELETE = 3
T_COMPACT = 4
T_SEQ_INSERT = 5


class WalCorruptError(CorruptError):
    """Mid-log corruption (not a torn tail): recovery refuses to proceed."""


class StaleEpochError(WalCorruptError):
    """A fenced (stale-epoch) writer tried to touch a log that a higher
    epoch already owns — the old primary after a failover.  Refusing here
    is what makes split-brain unable to corrupt the record stream."""


def segment_name(seq: int) -> str:
    return f"wal-{seq:08d}.seg"


def list_segments(dirpath: str) -> list[tuple[int, str]]:
    """(seq, path) pairs of the directory's WAL segments, seq-ascending."""
    out = []
    if os.path.isdir(dirpath):
        for name in os.listdir(dirpath):
            if name.startswith("wal-") and name.endswith(".seg"):
                try:
                    seq = int(name[4:-4])
                except ValueError:
                    continue
                out.append((seq, os.path.join(dirpath, name)))
    out.sort()
    return out


# ------------------------------------------------------------------ payloads
def pack_insert(vectors: np.ndarray, attrs: np.ndarray, backend: str,
                device_width: int | None, shards: int | None) -> bytes:
    head = canonical_json(
        {"backend": backend, "device_width": device_width, "shards": shards}
    )
    return (
        struct.pack("<I", len(head)) + head
        + encode_npy(np.asarray(vectors, np.float32))
        + encode_npy(np.asarray(attrs, np.float64))
    )


def unpack_insert(payload: bytes) -> tuple[np.ndarray, np.ndarray, dict]:
    (jlen,) = struct.unpack_from("<I", payload)
    head = json.loads(payload[4 : 4 + jlen])
    buf = _io.BytesIO(payload[4 + jlen :])
    vectors = np.load(buf, allow_pickle=False)
    attrs = np.load(buf, allow_pickle=False)
    return vectors, attrs, head


def pack_seq_insert(vec: np.ndarray, attr: float) -> bytes:
    return encode_npy(np.asarray(vec, np.float32).reshape(-1)) + encode_npy(
        np.asarray([attr], np.float64)
    )


def unpack_seq_insert(payload: bytes) -> tuple[np.ndarray, float]:
    buf = _io.BytesIO(payload)
    vec = np.load(buf, allow_pickle=False)
    attr = np.load(buf, allow_pickle=False)
    return vec, float(attr[0])


# ------------------------------------------------------------------- records
def encode_record(rtype: int, lsn: int, payload: bytes) -> bytes:
    body = struct.pack("<BQ", rtype, lsn) + payload
    return struct.pack("<II", len(body), crc32(body)) + body


def _try_parse_record(data: bytes, off: int):
    """Parse one record at ``off``; returns (lsn, type, payload, end) or
    None when the bytes there do not form a valid record."""
    if off + REC_OVERHEAD > len(data):
        return None
    length, stated = struct.unpack_from("<II", data, off)
    if length < MIN_BODY or off + REC_OVERHEAD + length > len(data):
        return None
    body = data[off + REC_OVERHEAD : off + REC_OVERHEAD + length]
    if crc32(body) != stated:
        return None
    rtype, lsn = struct.unpack_from("<BQ", body)
    return lsn, rtype, body[MIN_BODY:], off + REC_OVERHEAD + length


def _probe_valid_record(data: bytes, from_off: int) -> bool:
    """True when ANY byte offset >= ``from_off`` parses as a valid record —
    the torn-tail/corruption discriminator: a genuine torn tail is a pure
    garbage suffix, so a valid record beyond the damage proves mid-log
    corruption."""
    for off in range(from_off, len(data) - REC_OVERHEAD - MIN_BODY + 1):
        if _try_parse_record(data, off) is not None:
            return True
    return False


def encode_segment_header(seq: int, start_lsn: int, epoch: int = 0) -> bytes:
    head = struct.pack("<8sIIQQ", SEG_MAGIC, SEG_VERSION, epoch, seq,
                       start_lsn)
    return head + struct.pack("<I", crc32(head))


def parse_segment_header(data: bytes) -> dict | None:
    if len(data) < SEG_HEADER_LEN:
        return None
    magic, version, epoch, seq, start_lsn = struct.unpack_from("<8sIIQQ", data)
    (stated,) = struct.unpack_from("<I", data, 32)
    if magic != SEG_MAGIC or version != SEG_VERSION:
        return None
    if crc32(data[:32]) != stated:
        return None
    return {"seq": seq, "start_lsn": start_lsn, "epoch": epoch}


def log_epoch(dirpath: str) -> int:
    """Highest segment-header epoch in ``dirpath`` (0 if empty/unreadable).
    Epochs are non-decreasing across segments, so this is the epoch the
    log's most recent writer held — recovery folds it into the index
    because a promotion rotates the WAL without writing a checkpoint."""
    best = 0
    for _seq, path in list_segments(dirpath):
        try:
            with open(path, "rb") as f:
                hdr = parse_segment_header(f.read(SEG_HEADER_LEN))
        except OSError:
            continue
        if hdr is not None and hdr["epoch"] > best:
            best = hdr["epoch"]
    return best


def scan_segment(path: str) -> dict:
    """Parse a segment file fully.  Returns::

        {"header": dict | None, "records": [(lsn, type, payload, end_off)],
         "bad_off": int | None,   # offset of the first invalid record
         "valid_beyond": bool,    # a valid record exists past bad_off
         "size": int}

    ``header=None`` means the 36-byte header itself failed validation
    (``bad_off`` is then 0 and ``valid_beyond`` probes from the header end).
    """
    with open(path, "rb") as f:
        data = f.read()
    header = parse_segment_header(data)
    if header is None:
        return {
            "header": None,
            "records": [],
            "bad_off": 0,
            "valid_beyond": _probe_valid_record(data, SEG_HEADER_LEN),
            "size": len(data),
        }
    records = []
    off = SEG_HEADER_LEN
    expect = header["start_lsn"]
    while off < len(data):
        rec = _try_parse_record(data, off)
        if rec is None:
            return {
                "header": header,
                "records": records,
                "bad_off": off,
                "valid_beyond": _probe_valid_record(data, off + 1),
                "size": len(data),
            }
        lsn, rtype, payload, end = rec
        if lsn != expect:
            # a checksummed record with the wrong LSN is never a torn
            # tail — flag it as corruption via valid_beyond
            return {
                "header": header,
                "records": records,
                "bad_off": off,
                "valid_beyond": True,
                "size": len(data),
            }
        records.append((lsn, rtype, payload, end))
        expect += 1
        off = end
    return {
        "header": header,
        "records": records,
        "bad_off": None,
        "valid_beyond": False,
        "size": len(data),
    }


# -------------------------------------------------------------------- writer
class WalWriter:
    """Appends self-checksummed records to the newest segment, fsyncing
    each before returning its LSN (log -> fsync -> apply discipline lives
    in the `WoWIndex` hooks).  Rotation starts a fresh segment once the
    current one exceeds ``segment_bytes`` (and on every checkpoint, so
    pruning works at segment granularity)."""

    def __init__(self, dirpath: str, io: OsIO | None = None,
                 segment_bytes: int = 4 << 20, epoch: int | None = None,
                 start_lsn: int = 1):
        """``epoch=None`` adopts the newest segment's epoch (0 for a fresh
        log).  An explicit epoch below the log's is refused with
        ``StaleEpochError`` — a fenced ex-primary reopening a log its
        successor already wrote; an explicit epoch above it rotates
        immediately so the promotion is stamped on disk before any append.
        ``start_lsn`` seeds the first segment of an *empty* directory — a
        bootstrapped replica's WAL starts at its checkpoint LSN + 1, not
        at 1 — and is ignored when segments exist."""
        self.dir = dirpath
        self.io = io or OsIO()
        self.segment_bytes = segment_bytes
        self.io.mkdir(dirpath)
        self._f = None
        self._size = 0
        segs = list_segments(dirpath)
        if segs:
            segs = self._verify_chain(segs)
        if not segs:
            self.next_lsn = start_lsn
            self._seq = -1
            self.epoch = 0 if epoch is None else epoch
            self.rotate()
            return
        seq, path = segs[-1]
        scan = scan_segment(path)
        if scan["bad_off"] is not None or scan["header"] is None:
            raise WalCorruptError(
                f"cannot append to {path}: invalid tail at offset "
                f"{scan['bad_off']} (run recovery first)"
            )
        tail_epoch = scan["header"]["epoch"]
        if epoch is not None and epoch < tail_epoch:
            raise StaleEpochError(
                f"cannot append to {path}: writer epoch {epoch} is behind "
                f"log epoch {tail_epoch} (fenced by a newer primary)"
            )
        self.epoch = tail_epoch if epoch is None else epoch
        self._seq = seq
        self.next_lsn = (
            scan["records"][-1][0] + 1 if scan["records"]
            else scan["header"]["start_lsn"]
        )
        if self.epoch > tail_epoch:
            # stamp the promotion before any append lands in the log
            self.rotate()
            return
        self._f = self.io.open_append(path)
        self._size = scan["size"]

    def _verify_chain(self, segs: list[tuple[int, str]]):
        """Cross-segment epoch + LSN continuity for the WHOLE chain on
        reopen (``read_log`` checks this on the recovery path; a writer
        reopening after a ``prune()``/``rotate()`` crash must not trust the
        tail segment alone).  A torn *final* header — the crash landed
        mid-``rotate``, before the new segment's header was fully written
        and with no records in it — is removed so the previous segment
        becomes the tail again; anything else invalid raises."""
        prev_end: int | None = None
        prev_epoch: int | None = None
        for i, (seq, path) in enumerate(segs):
            last = i == len(segs) - 1
            scan = scan_segment(path)
            hdr = scan["header"]
            if hdr is None:
                if last and not scan["valid_beyond"]:
                    self.io.remove(path)
                    self.io.fsync_dir(self.dir)
                    return segs[:-1]
                raise WalCorruptError(f"{path}: invalid segment header")
            if scan["bad_off"] is not None and not last:
                raise WalCorruptError(
                    f"{path}: invalid record at offset {scan['bad_off']} in "
                    f"a non-final segment (run recovery first)"
                )
            if prev_epoch is not None and hdr["epoch"] < prev_epoch:
                raise WalCorruptError(
                    f"{path}: epoch went backwards ({prev_epoch} -> "
                    f"{hdr['epoch']})"
                )
            if prev_end is not None and hdr["start_lsn"] != prev_end:
                raise WalCorruptError(
                    f"{path}: start_lsn {hdr['start_lsn']} breaks LSN "
                    f"continuity (previous segment ended at {prev_end})"
                )
            prev_end = (
                scan["records"][-1][0] + 1 if scan["records"]
                else hdr["start_lsn"]
            )
            prev_epoch = hdr["epoch"]
        return segs

    def rotate(self) -> None:
        """Close the current segment and start ``seq+1`` at ``next_lsn``,
        stamped with the writer's current epoch."""
        if self._f is not None:
            self.io.fsync(self._f)
            self.io.close(self._f)
        self._seq += 1
        path = os.path.join(self.dir, segment_name(self._seq))
        self._f = self.io.create(path)
        hdr = encode_segment_header(self._seq, self.next_lsn, self.epoch)
        self.io.write(self._f, hdr)
        self.io.fsync(self._f)
        self.io.fsync_dir(self.dir)
        self._size = len(hdr)

    def set_epoch(self, epoch: int) -> None:
        """Adopt a higher epoch, rotating so the fence is on disk before
        any record of the new term.  Moving backwards is refused; equal is
        a no-op (epoch comparisons are strict by contract)."""
        if epoch < self.epoch:
            raise StaleEpochError(
                f"epoch may not move backwards ({self.epoch} -> {epoch})"
            )
        if epoch > self.epoch:
            self.epoch = epoch
            self.rotate()

    def append(self, rtype: int, payload: bytes = b"",
               fsync: bool = True) -> int:
        """Append one record; returns its LSN.  With ``fsync`` (default)
        the record is durable when this returns.  ``fsync=False`` is the
        group-commit half: the caller batches several appends and makes
        them all durable with one ``sync()`` — the serve engine's ingest
        admission logs every queued micro-batch this way and acks after a
        single fsync, so durability order still equals admission order at
        a fraction of the fsync cost.  A crash before the ``sync()``
        tears an *unacked* suffix, which recovery truncates like any torn
        tail."""
        if self._size >= self.segment_bytes:
            self.rotate()
        lsn = self.next_lsn
        rec = encode_record(rtype, lsn, payload)
        self.io.write(self._f, rec)
        if fsync:
            self.io.fsync(self._f)
        self._size += len(rec)
        self.next_lsn = lsn + 1
        return lsn

    def sync(self) -> None:
        """Make every appended record durable (the group-commit barrier)."""
        if self._f is not None:
            self.io.fsync(self._f)

    # typed appends (the WoWIndex hooks call these)
    def log_insert(self, vectors, attrs, backend: str,
                   device_width: int | None, shards: int | None,
                   fsync: bool = True) -> int:
        return self.append(
            T_INSERT,
            pack_insert(vectors, attrs, backend, device_width, shards),
            fsync=fsync,
        )

    def log_seq_insert(self, vec, attr: float) -> int:
        return self.append(T_SEQ_INSERT, pack_seq_insert(vec, attr))

    def log_delete(self, vid: int) -> int:
        return self.append(T_DELETE, canonical_json({"vid": int(vid)}))

    def log_undelete(self, vid: int) -> int:
        return self.append(T_UNDELETE, canonical_json({"vid": int(vid)}))

    def log_compact(self) -> int:
        return self.append(T_COMPACT)

    def prune(self, keep_from_lsn: int) -> int:
        """Delete segments whose records are ALL <= ``keep_from_lsn`` (i.e.
        already covered by every retained checkpoint).  The last segment is
        never deleted.  Returns the number of segments removed."""
        segs = list_segments(self.dir)
        removed = 0
        for i, (seq, path) in enumerate(segs[:-1]):
            nxt_scan = scan_segment(segs[i + 1][1])
            nxt_start = (
                nxt_scan["header"]["start_lsn"] if nxt_scan["header"] else None
            )
            if nxt_start is not None and nxt_start <= keep_from_lsn + 1:
                self.io.remove(path)
                removed += 1
            else:
                break  # segments are lsn-ordered: nothing older is prunable
        if removed:
            self.io.fsync_dir(self.dir)
        return removed

    def close(self) -> None:
        if self._f is not None:
            self.io.fsync(self._f)
            self.io.close(self._f)
            self._f = None


# -------------------------------------------------------------------- replay
def read_log(dirpath: str, io: OsIO | None = None,
             truncate_torn: bool = True) -> list[tuple[int, int, bytes]]:
    """Validate the whole log and return its records as (lsn, type,
    payload), lsn-ascending and gap-free.

    Torn tails (invalid suffix of the LAST segment with nothing valid
    beyond it) are truncated away when ``truncate_torn`` — the recovery
    path — so a subsequent ``WalWriter`` can append cleanly.  Anything
    else invalid raises ``WalCorruptError``.
    """
    io = io or OsIO()
    segs = list_segments(dirpath)
    out: list[tuple[int, int, bytes]] = []
    expect: int | None = None
    for i, (seq, path) in enumerate(segs):
        last = i == len(segs) - 1
        scan = scan_segment(path)
        if scan["header"] is None:
            if not last or scan["valid_beyond"]:
                raise WalCorruptError(f"{path}: invalid segment header")
            # torn segment creation: header never fully landed, no records
            if truncate_torn:
                io.remove(path)
            break
        if scan["bad_off"] is not None:
            if not last or scan["valid_beyond"]:
                raise WalCorruptError(
                    f"{path}: invalid record at offset {scan['bad_off']} "
                    f"with valid data beyond it (corruption, not a torn tail)"
                )
            if truncate_torn:
                io.truncate(path, scan["bad_off"])
        if scan["records"]:
            first = scan["records"][0][0]
            if expect is not None and first != expect:
                raise WalCorruptError(
                    f"{path}: LSN gap (expected {expect}, found {first})"
                )
            out.extend((l, t, p) for l, t, p, _ in scan["records"])
            expect = scan["records"][-1][0] + 1
        elif expect is not None and scan["header"]["start_lsn"] > expect:
            raise WalCorruptError(
                f"{path}: start_lsn {scan['header']['start_lsn']} leaves an "
                f"LSN gap (expected {expect})"
            )
    return out


def apply_record(index, rtype: int, payload: bytes) -> None:
    """Re-execute one logged mutation on ``index`` (replay mode: the index
    must have ``_wal_replaying`` set so the apply neither re-logs nor
    re-triggers auto-compaction — compactions replay via their own
    records)."""
    if rtype == T_INSERT:
        vectors, attrs, head = unpack_insert(payload)
        backend = head["backend"]
        shards = head["shards"]
        if backend == "sharded":
            # the sharded build is bitwise the device build at every shard
            # count, so replay is device-count independent
            backend, shards = "device", None
        index.insert_batch(
            vectors, attrs, batch_size=max(len(attrs), 1), backend=backend,
            device_width=head["device_width"], shards=shards,
        )
    elif rtype == T_SEQ_INSERT:
        vec, attr = unpack_seq_insert(payload)
        index.insert(vec, attr)
    elif rtype == T_DELETE:
        index.delete(json.loads(payload)["vid"])
    elif rtype == T_UNDELETE:
        index.undelete(json.loads(payload)["vid"])
    elif rtype == T_COMPACT:
        index.compact_rows()
    else:
        raise WalCorruptError(f"unknown WAL record type {rtype}")

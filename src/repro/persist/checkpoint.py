"""Versioned checkpoints of a `WoWIndex` (full + incremental/delta).

A checkpoint directory serializes everything a bitwise restore needs:

* vector/attr/norm slabs (``store`` prefixes ``[:n]``),
* the layered graph (stacked adjacency + counts prefixes),
* WBT state (``val[:wn]`` — which IS the insertion order, so replaying
  ``wbt.insert`` per value reconstructs ``left/right/size/root`` bit for
  bit),
* tombstones (``deleted``; the dead-value list and live counts are
  reconstructed deterministically from attrs + tombstones),
* RNG/mutation stamps (``np.random.Generator`` bit-generator state as
  JSON, ``mutations``, ``graph.version``, build stats — so ``describe()``
  and all later stochastic choices round-trip exactly),
* the delta-arena tail is NOT serialized: build arenas/slabs/visited pools
  are derived caches that any backend rebuilds lazily (amortised) and that
  never influence committed results.

Incremental checkpoints ride the index's second dirty-row tracker
(``_ckpt_tracker``, fed by the same ``_commit_deltas`` that feeds
``take_snapshot(prev=)``): a delta saves only the store/WBT tails since the
base checkpoint, the dirty graph rows, and the (small) tombstone/meta
sections — steady-state checkpoints are O(changed rows).  Chains are capped
at ``full_every`` deltas before the next save is forced full.

Atomicity: sections + manifest land in ``<name>.tmp``, the tmp dir is
fsynced, then ``os.replace``d into place and the parent fsynced — readers
see either the old set of checkpoints or the new one, never a torn write.
Retention keeps the two newest checkpoints plus their delta-chain bases.
"""
from __future__ import annotations

import hashlib
import json
import os

import numpy as np

from .faultfs import OsIO
from .format import (
    CorruptError,
    canonical_json,
    read_manifest,
    read_section,
    write_manifest,
    write_section,
)

CKPT_SUBDIR = "checkpoints"
CKPT_PREFIX = "ckpt-"


def checkpoint_dir(root: str) -> str:
    return os.path.join(root, CKPT_SUBDIR)


def list_checkpoints(root: str) -> list[tuple[int, str]]:
    """(seq, path) pairs of finalized checkpoints, seq-ascending."""
    d = checkpoint_dir(root)
    out = []
    if os.path.isdir(d):
        for name in os.listdir(d):
            if name.startswith(CKPT_PREFIX) and not name.endswith(".tmp"):
                try:
                    seq = int(name[len(CKPT_PREFIX):])
                except ValueError:
                    continue
                out.append((seq, os.path.join(d, name)))
    out.sort()
    return out


def _index_meta(index) -> dict:
    p = index.params
    bs = index.build_stats
    return {
        "n": int(index.store.n),
        "dim": int(index.store.dim),
        "m": int(p.m),
        "ef_construction": int(p.ef_construction),
        "o": int(p.o),
        "metric": p.metric,
        "seed": int(p.seed),
        "store_cap": int(index.store.capacity),
        "graph_cap": int(index.graph.capacity),
        "wbt_cap": int(index.wbt._cap),
        "wn": int(index.wbt.n),
        "num_layers": int(index.graph.num_layers),
        "vec_dtype": getattr(index, "vec_dtype", "f32"),
        "graph_version": int(index.graph.version),
        "mutations": int(index.mutations),
        "lsn": int(getattr(index, "_applied_lsn", 0)),
        "epoch": int(getattr(index, "_epoch", 0)),
        "compact_dead_done": int(getattr(index, "_compact_dead_done", 0)),
        "build_stats": {
            "dc": int(bs.dc),
            "searches": int(bs.searches),
            "searches_skipped": int(bs.searches_skipped),
            "prunes": int(bs.prunes),
        },
        "rng_state": _jsonable(index._rng.bit_generator.state),
    }


def _jsonable(obj):
    """bit_generator.state can contain numpy scalars; normalize to JSON."""
    return json.loads(json.dumps(obj, default=int))


# ---------------------------------------------------------------------- save
def save(index, root: str, io: OsIO | None = None, incremental: bool = True,
         full_every: int = 8) -> str:
    """Write a checkpoint of ``index`` under ``<root>/checkpoints/``.

    Incremental when possible (see module docstring); falls back to a full
    checkpoint whenever the dirty tracker cannot vouch for the interval
    since the newest checkpoint.  After a successful save: the tracker is
    reset, retention keeps the two newest checkpoints (plus delta bases),
    and — when the index has a WAL attached — the log is rotated and
    segments covered by every retained checkpoint are pruned.

    Returns the new checkpoint's path.
    """
    io = io or OsIO()
    # the checkpoint boundary is also a compaction-cadence boundary
    index._maybe_auto_compact()
    io.mkdir(checkpoint_dir(root))
    existing = list_checkpoints(root)
    seq = (existing[-1][0] + 1) if existing else 1

    base = None  # (manifest, path)
    if incremental and existing:
        try:
            bman = read_manifest(existing[-1][1])
        except CorruptError:
            bman = None
        tr = index._ckpt_tracker
        if (
            bman is not None
            and not tr["all"]
            and bman["meta"]["mutations"] == tr["stamp"]
            and bman.get("depth", 0) + 1 < full_every
            and bman["meta"]["n"] <= index.store.n
            and bman["meta"]["num_layers"] <= index.graph.num_layers
            and bman["meta"]["wn"] <= index.wbt.n
            and bman["meta"]["m"] == index.params.m
            and bman["meta"].get("vec_dtype", "f32")
            == getattr(index, "vec_dtype", "f32")
        ):
            base = (bman, existing[-1][1])

    name = f"{CKPT_PREFIX}{seq:08d}"
    final = os.path.join(checkpoint_dir(root), name)
    tmp = final + ".tmp"
    io.remove(tmp)
    io.mkdir(tmp)
    sections: dict[str, dict] = {}
    meta = _index_meta(index)
    n = meta["n"]
    L = meta["num_layers"]
    st, g = index.store, index.graph

    def put(sname: str, arr: np.ndarray) -> None:
        sections[sname] = write_section(io, tmp, sname, arr)

    # quantized serving slabs (format v2): the storage-dtype vector slab +
    # per-row int8 scales ride alongside the f32 oracle sections, so the
    # serve-from-checkpoint cold start maps them directly instead of
    # re-quantizing n*d floats.  Per-row quantization makes delta tails
    # bitwise identical to slices of a full-slab quantization.
    vec_dtype = getattr(index, "vec_dtype", "f32")

    def put_quantized(lo: int, hi: int, suffix: str = "") -> None:
        if vec_dtype == "f32":
            return
        from ..core.store import quantize_rows

        slab, scales = quantize_rows(st.vectors[lo:hi], vec_dtype)
        put(f"q_vectors{suffix}", slab.view(np.uint16)
            if vec_dtype == "bf16" else slab)
        if scales is not None:
            put(f"q_scales{suffix}", scales)

    if base is None:
        put("vectors", st.vectors[:n])
        put("attrs", st.attrs[:n])
        put("sq_norms", st.sq_norms[:n])
        put_quantized(0, n)
        put("neighbors", np.stack([lay[:n] for lay in g.layers])
            if n else np.zeros((L, 0, g.m), np.int32))
        put("counts", np.stack([c[:n] for c in g.counts])
            if n else np.zeros((L, 0), np.int32))
        put("wbt_vals", index.wbt.val[: index.wbt.n])
        manifest = {"kind": "full", "seq": seq, "base": None, "depth": 0}
    else:
        bman, _ = base
        bn = bman["meta"]["n"]
        bL = bman["meta"]["num_layers"]
        bwn = bman["meta"]["wn"]
        put("vectors_tail", st.vectors[bn:n])
        put("attrs_tail", st.attrs[bn:n])
        put("sq_norms_tail", st.sq_norms[bn:n])
        put_quantized(bn, n, suffix="_tail")
        put("wbt_vals_tail", index.wbt.val[bwn: index.wbt.n])
        dirty = index._ckpt_tracker["dirty"]
        for l in range(L):
            if l < bL:
                parts = dirty.get(l, ())
                rows = (
                    np.unique(np.concatenate([np.asarray(p) for p in parts]))
                    if parts else np.empty(0, np.int64)
                )
                rows = rows[rows < bn]
                put(f"dirty_rows_{l}", rows)
                put(f"dirty_nbr_{l}", g.layers[l][rows])
                put(f"dirty_cnt_{l}", g.counts[l][rows])
                put(f"tail_nbr_{l}", g.layers[l][bn:n])
                put(f"tail_cnt_{l}", g.counts[l][bn:n])
            else:
                put(f"full_nbr_{l}", g.layers[l][:n])
                put(f"full_cnt_{l}", g.counts[l][:n])
        manifest = {
            "kind": "delta",
            "seq": seq,
            "base": bman["seq"],
            "depth": bman.get("depth", 0) + 1,
        }

    deleted = np.fromiter(sorted(index.deleted), dtype=np.int64,
                          count=len(index.deleted))
    put("deleted", deleted)
    # dead values are stored f32 (format v2): attrs are canonicalized to
    # exactly-f32-representable values at ingest, so f32 is lossless here —
    # a f64 section would let a value that differs under f64<->f32 round
    # through recovery and silently resurrect in ``selectivity``.  v1
    # checkpoints have no section; readers reconstruct from attrs+deleted.
    put("dead_vals", np.asarray(index._dead_vals, np.float32))
    manifest["meta"] = meta
    manifest["sections"] = sections
    write_manifest(io, tmp, manifest)
    io.fsync_dir(tmp)
    io.replace(tmp, final)
    io.fsync_dir(checkpoint_dir(root))

    # checkpoint durable: reset the dirty tracker to this new base
    index._ckpt_tracker = {"stamp": index.mutations, "all": False, "dirty": {}}

    _retain(root, io, keep=2)
    wal = getattr(index, "_wal", None)
    if wal is not None:
        wal.rotate()
        kept = _retained_lsns(root)
        if kept:
            wal.prune(min(kept))
    return final


def _chain_seqs(root: str, seq: int) -> set[int]:
    """The checkpoint's full delta chain (itself + transitive bases)."""
    by_seq = dict(list_checkpoints(root))
    out = set()
    cur: int | None = seq
    while cur is not None and cur in by_seq and cur not in out:
        out.add(cur)
        try:
            cur = read_manifest(by_seq[cur]).get("base")
        except CorruptError:
            break
    return out

def _retain(root: str, io: OsIO, keep: int = 2) -> None:
    ckpts = list_checkpoints(root)
    keep_seqs: set[int] = set()
    for seq, _ in ckpts[-keep:]:
        keep_seqs |= _chain_seqs(root, seq)
    removed = False
    for seq, path in ckpts:
        if seq not in keep_seqs:
            io.remove(path)
            removed = True
    if removed:
        io.fsync_dir(checkpoint_dir(root))


def _retained_lsns(root: str) -> list[int]:
    out = []
    for _, path in list_checkpoints(root):
        try:
            out.append(read_manifest(path)["meta"]["lsn"])
        except CorruptError:
            continue
    return out


# ---------------------------------------------------------------------- load
def _load_state(root: str, seq: int, mmap: bool = False) -> dict:
    """Compose the checkpoint chain ending at ``seq`` into host arrays.

    With ``mmap`` (full checkpoints only) the big slabs are memory mapped
    after CRC validation — the serve-from-checkpoint cold start.
    """
    by_seq = dict(list_checkpoints(root))
    if seq not in by_seq:
        raise CorruptError(f"checkpoint {seq} missing (broken delta chain)")
    path = by_seq[seq]
    man = read_manifest(path)
    meta = man["meta"]
    sec = man["sections"]
    n, L, m = meta["n"], meta["num_layers"], meta["m"]

    def rd(name: str, use_mmap: bool = False) -> np.ndarray:
        if name not in sec:
            raise CorruptError(f"checkpoint {seq}: missing section {name!r}")
        return read_section(path, name, sec[name], mmap=use_mmap)

    vec_dtype = meta.get("vec_dtype", "f32")

    def view_q(arr: np.ndarray) -> np.ndarray:
        # bf16 slabs are stored as their uint16 bit pattern (plain-npy
        # portability); reinterpret — mmap-safe, no copy
        if vec_dtype == "bf16":
            import ml_dtypes

            return arr.view(ml_dtypes.bfloat16)
        return arr

    if man["kind"] == "full":
        state = {
            "vectors": rd("vectors", mmap),
            "attrs": rd("attrs"),
            "sq_norms": rd("sq_norms", mmap),
            "neighbors": rd("neighbors", mmap),
            "counts": rd("counts"),
            "wbt_vals": rd("wbt_vals"),
        }
        if "q_vectors" in sec:
            state["q_vectors"] = view_q(rd("q_vectors", mmap))
        if "q_scales" in sec:
            state["q_scales"] = rd("q_scales", mmap)
    else:
        base = _load_state(root, man["base"], mmap=False)
        bn = base["meta"]["n"]
        bL = base["meta"]["num_layers"]
        if bn != base["vectors"].shape[0]:
            raise CorruptError(f"checkpoint {seq}: base shape mismatch")
        state = {
            "vectors": np.concatenate([base["vectors"], rd("vectors_tail")]),
            "attrs": np.concatenate([base["attrs"], rd("attrs_tail")]),
            "sq_norms": np.concatenate(
                [base["sq_norms"], rd("sq_norms_tail")]
            ),
            "wbt_vals": np.concatenate(
                [base["wbt_vals"], rd("wbt_vals_tail")]
            ),
        }
        if "q_vectors_tail" in sec:
            state["q_vectors"] = np.concatenate(
                [np.asarray(base["q_vectors"]),
                 view_q(rd("q_vectors_tail"))]
            )
        if "q_scales_tail" in sec:
            state["q_scales"] = np.concatenate(
                [np.asarray(base["q_scales"]), rd("q_scales_tail")]
            )
        neighbors = np.empty((L, n, m), np.int32)
        counts = np.empty((L, n), np.int32)
        for l in range(L):
            if l < bL:
                neighbors[l, :bn] = base["neighbors"][l]
                counts[l, :bn] = base["counts"][l]
                neighbors[l, bn:] = rd(f"tail_nbr_{l}")
                counts[l, bn:] = rd(f"tail_cnt_{l}")
                rows = rd(f"dirty_rows_{l}")
                if rows.size:
                    neighbors[l, rows] = rd(f"dirty_nbr_{l}")
                    counts[l, rows] = rd(f"dirty_cnt_{l}")
            else:
                neighbors[l] = rd(f"full_nbr_{l}")
                counts[l] = rd(f"full_cnt_{l}")
        state["neighbors"] = neighbors
        state["counts"] = counts
    state["deleted"] = rd("deleted")
    # v1 checkpoints predate the explicit f32 dead-value section; readers
    # migrate by reconstructing from attrs + tombstones (see materialize)
    state["dead_vals"] = rd("dead_vals") if "dead_vals" in sec else None
    state["meta"] = meta
    if state["vectors"].shape != (n, meta["dim"]) or state[
        "wbt_vals"
    ].shape != (meta["wn"],):
        raise CorruptError(f"checkpoint {seq}: composed shape mismatch")
    return state


def load_state(root: str, mmap: bool = False) -> dict:
    """Compose the newest *valid* checkpoint chain; a corrupt newest
    checkpoint falls back to the next older one (clean refusal only when
    none validates)."""
    ckpts = list_checkpoints(root)
    if not ckpts:
        raise CorruptError(f"no checkpoints under {checkpoint_dir(root)}")
    err: Exception | None = None
    for seq, _ in reversed(ckpts):
        try:
            return _load_state(root, seq, mmap=mmap)
        except CorruptError as e:
            err = e
    raise CorruptError(f"no valid checkpoint under {root}: {err}")


def materialize(state: dict):
    """Rebuild a live `WoWIndex` from composed checkpoint state, bitwise
    identical (over all meaningful prefixes) to the index that saved it."""
    from ..core.graph import PAD, LayeredGraph
    from ..core.index import WoWIndex
    from ..core.store import VectorStore
    from ..core.wbt import WBT

    meta = state["meta"]
    n, L, m = meta["n"], meta["num_layers"], meta["m"]
    index = WoWIndex(
        dim=meta["dim"], m=m, ef_construction=meta["ef_construction"],
        o=meta["o"], metric=meta["metric"], seed=meta["seed"],
        vec_dtype=meta.get("vec_dtype", "f32"),
    )
    st = VectorStore(meta["dim"], metric=meta["metric"],
                     capacity=meta["store_cap"])
    st.vectors[:n] = state["vectors"]
    st.attrs[:n] = state["attrs"]
    st.sq_norms[:n] = state["sq_norms"]
    st.n = n
    st.attrs_list = st.attrs[:n].tolist()
    index.store = st

    g = LayeredGraph(m, capacity=meta["graph_cap"])
    for _ in range(L - 1):
        g.add_layer()
    for l in range(L):
        g.layers[l][:n] = state["neighbors"][l]
        g.layers[l][n:] = PAD
        g.counts[l][:n] = state["counts"][l]
        g.counts[l][n:] = 0
    g.version = meta["graph_version"]
    index.graph = g

    wbt = WBT(capacity=meta["wbt_cap"])
    for v in state["wbt_vals"].tolist():
        wbt.insert(v)
    index.wbt = wbt

    index.deleted = set(state["deleted"].tolist())
    # value_map / live counts / dead values are fully determined by
    # (attrs, deleted): vids ascend in insertion order, so id-order
    # reconstruction reproduces the live dict contents exactly
    value_map: dict[float, list[int]] = {}
    live: dict[float, int] = {}
    for vid, val in enumerate(st.attrs_list):
        value_map.setdefault(val, []).append(vid)
        live[val] = live.get(val, 0) + (0 if vid in index.deleted else 1)
    index.value_map = value_map
    index._live_counts = live
    if state.get("dead_vals") is not None:
        # v2: the f32 section is authoritative — attrs are f32-canonical
        # at ingest, so float(np.float32) round-trips exactly onto the
        # host f64 order keys (no resurrection after recovery)
        index._dead_vals = [float(v) for v in state["dead_vals"]]
    else:  # v1 migrate-on-read: reconstruct from attrs + tombstones
        index._dead_vals = sorted(v for v, c in live.items() if c == 0)

    index.mutations = meta["mutations"]
    bs = meta["build_stats"]
    index.build_stats.dc = bs["dc"]
    index.build_stats.searches = bs["searches"]
    index.build_stats.searches_skipped = bs["searches_skipped"]
    index.build_stats.prunes = bs["prunes"]
    index._rng.bit_generator.state = meta["rng_state"]
    index._compact_dead_done = meta["compact_dead_done"]
    index._applied_lsn = meta["lsn"]
    # fencing epoch (0 on pre-replication checkpoints); like _applied_lsn
    # it is positional metadata, deliberately outside the state digest
    index._epoch = meta.get("epoch", 0)
    # a just-loaded index IS the newest checkpoint's state: the ckpt
    # tracker can vouch for deltas from here on
    index._ckpt_tracker = {"stamp": index.mutations, "all": False,
                           "dirty": {}}
    index._snap_tracker = {"stamp": -1, "all": True, "dirty": {}}
    return index


def load(root: str):
    """`materialize(load_state(root))` — restore without WAL replay."""
    return materialize(load_state(root))


# -------------------------------------------------------- equality / digests
def index_arrays(index) -> list[tuple[str, np.ndarray]]:
    """Canonical (name, array) list covering every meaningful prefix of
    index state — the comparison basis for round-trip and fault-sweep
    bitwise-equality assertions."""
    n = index.store.n
    wn = index.wbt.n
    out = [
        ("vectors", index.store.vectors[:n]),
        ("attrs", index.store.attrs[:n]),
        ("sq_norms", index.store.sq_norms[:n]),
        ("wbt_val", index.wbt.val[:wn]),
        ("wbt_left", index.wbt.left[:wn]),
        ("wbt_right", index.wbt.right[:wn]),
        ("wbt_size", index.wbt.size[:wn]),
        ("deleted", np.fromiter(sorted(index.deleted), np.int64,
                                count=len(index.deleted))),
        # f32, matching the checkpoint section: attrs are f32-canonical at
        # ingest, so this is lossless — and a f64 basis here would mask a
        # writer that narrows dead values on the way to disk
        ("dead_vals", np.asarray(index._dead_vals, np.float32)),
    ]
    for l in range(index.graph.num_layers):
        out.append((f"nbr_{l}", index.graph.layers[l][:n]))
        out.append((f"cnt_{l}", index.graph.counts[l][:n]))
    return out


def index_scalars(index) -> dict:
    bs = index.build_stats
    return {
        "n": int(index.store.n),
        "wn": int(index.wbt.n),
        "wbt_root": int(index.wbt.root),
        "num_layers": int(index.graph.num_layers),
        "graph_version": int(index.graph.version),
        "mutations": int(index.mutations),
        "compact_dead_done": int(getattr(index, "_compact_dead_done", 0)),
        "build_stats": [int(bs.dc), int(bs.searches),
                        int(bs.searches_skipped), int(bs.prunes)],
        "params": [index.params.m, index.params.ef_construction,
                   index.params.o, index.params.metric, index.params.seed],
        "rng_state": _jsonable(index._rng.bit_generator.state),
    }


def state_digest(index) -> str:
    """sha256 over the canonical state (arrays + scalars) — two indices
    with equal digests are bitwise-identical over every prefix that can
    ever influence results (``_applied_lsn`` excluded: a WAL-replayed
    index and a never-logged reference are otherwise identical)."""
    h = hashlib.sha256()
    h.update(canonical_json(index_scalars(index)))
    for name, arr in index_arrays(index):
        h.update(name.encode())
        h.update(str(arr.dtype).encode())
        h.update(str(arr.shape).encode())
        h.update(np.ascontiguousarray(arr).tobytes())
    return h.hexdigest()


def assert_index_equal(a, b) -> None:
    """Bitwise equality over the canonical state; raises AssertionError
    naming the first differing field."""
    sa, sb = index_scalars(a), index_scalars(b)
    assert sa == sb, f"scalar state differs: {sa} != {sb}"
    for (name, xa), (_, xb) in zip(index_arrays(a), index_arrays(b)):
        assert xa.dtype == xb.dtype and xa.shape == xb.shape, (
            f"{name}: dtype/shape {xa.dtype}{xa.shape} != {xb.dtype}{xb.shape}"
        )
        assert np.array_equal(xa, xb), f"array {name!r} differs"
    assert a.describe() == b.describe()

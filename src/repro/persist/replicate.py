"""Primary -> replica replication: WAL shipping, quorum acks, epoch fencing.

The unit of replication is the existing self-checksummed WAL record: the
primary appends to its own log (`ReplicatedWal`, a `WalWriter`) and ships
every record to its replicas over an injectable transport; the PR 7
group-commit barrier (``append(fsync=False)`` ... ``sync()``) *is* the
quorum barrier — ``sync()`` returns only after the local fsync AND the
configured quorum of replicas have fsynced the records, so the serve
engine's ingest ack (which already sits behind ``wal.sync()``) becomes a
quorum-durable ack with no engine changes.

A replica (`ReplicaReplicator`) appends each record to its own WAL at the
same LSN, fsyncs, applies it under the ``_wal_replaying`` guard (no
re-log, no auto-compaction — the same replay discipline recovery uses),
and sends a cumulative ACK.  Out-of-order arrivals buffer; gaps NACK the
expected LSN and the primary re-ships.  Two indices that acked the same
LSN are bitwise-equal (``state_digest``) by the PR 6 replay contract.

Bootstrap: a fresh replica streams the primary's newest *full* checkpoint
chunk-by-chunk (per-chunk CRC32 from the manifest section table), then
catches up from the WAL suffix.  A dropped chunk or a replica crash
mid-bootstrap resumes by re-requesting only the chunks whose bytes on
disk fail their CRC — never the full copy.

Fencing: every WAL segment header and checkpoint manifest carries an
epoch/term.  Promotion bumps the epoch and rotates, so the fence is on
disk before any new-term record; a replica refuses appends whose epoch is
*strictly* below its own (replying FENCED), and a fenced primary's
``ReplicatedWal`` raises `StaleEpochError` instead of acking.  Epoch
comparisons are strict (``<``/``>``) by contract — enforced by the
``replication-ordering`` wowlint pass.

Transports: `InProcTransport` (deterministic in-process queues, the test
harness default), `SocketEndpoint` (localhost TCP for cross-process
failover tests), and `FaultTransport` (a faultfs-style deterministic
fault schedule — drop / duplicate / delay-reorder / partition keyed by
per-link message sequence number) wrapping either.
"""
from __future__ import annotations

import json
import os
import select
import socket
import struct
import time
from collections import OrderedDict, deque

from . import checkpoint as _ckpt
from . import recovery as _recovery
from .faultfs import OsIO
from .format import (
    MANIFEST_NAME,
    STREAM_CHUNK_BYTES,
    CorruptError,
    canonical_json,
    chunk_crcs,
    crc32,
    read_manifest,
)
from .wal import StaleEpochError, WalCorruptError, WalWriter, apply_record
from .wal import read_log as _read_log

# ------------------------------------------------------------- message codec
MSG_HELLO = 1
MSG_APPEND = 2
MSG_ACK = 3
MSG_NACK = 4
MSG_HEARTBEAT = 5
MSG_FENCED = 6
MSG_BOOT_REQ = 7
MSG_CKPT_META = 8
MSG_CKPT_CHUNK = 9
MSG_CKPT_DONE = 10

BOOT_PART_NAME = "MANIFEST.part"


class QuorumTimeoutError(RuntimeError):
    """The configured quorum did not fsync within the pump budget — the
    write is NOT acked (it may still be locally durable); the caller
    treats this as backpressure/unavailability, never as success."""


def encode_msg(kind: int, head: dict, payload: bytes = b"") -> bytes:
    """One replication message: u32 crc | u8 kind | u32 jlen | canonical
    JSON head | raw payload.  The CRC covers everything after it, so a
    corrupt frame is dropped at decode (retransmission heals it)."""
    hj = canonical_json(head)
    body = struct.pack("<BI", kind, len(hj)) + hj + payload
    return struct.pack("<I", crc32(body)) + body


def decode_msg(data: bytes) -> tuple[int, dict, bytes]:
    if len(data) < 9:
        raise CorruptError("replication frame too short")
    (stated,) = struct.unpack_from("<I", data)
    body = data[4:]
    if crc32(body) != stated:
        raise CorruptError("replication frame CRC mismatch")
    kind, jlen = struct.unpack_from("<BI", body)
    head = json.loads(body[5:5 + jlen])
    return kind, head, body[5 + jlen:]


# ---------------------------------------------------------------- transports
class InProcTransport:
    """Ordered, lossless in-process message queues keyed by node id — the
    deterministic base layer the fault schedule wraps.  ``kill()`` models
    process death: the node's queue vanishes and sends to it fail."""

    def __init__(self):
        self._queues: dict[str, deque] = {}

    def register(self, node_id: str) -> None:
        self._queues.setdefault(node_id, deque())

    def kill(self, node_id: str) -> None:
        self._queues.pop(node_id, None)

    def alive(self, node_id: str) -> bool:
        return node_id in self._queues

    def send(self, src: str, dst: str, data: bytes) -> bool:
        q = self._queues.get(dst)
        if q is None:
            return False
        q.append((src, data))
        return True

    def poll(self, node_id: str) -> list[tuple[str, bytes]]:
        q = self._queues.get(node_id)
        if not q:
            return []
        out = list(q)
        q.clear()
        return out


class FaultSchedule:
    """Deterministic per-link fault plan keyed by the link's message
    sequence number (1-based, counted per (src, dst) direction):

    * ``drop``       — iterable of (src, dst, seq): message vanishes
    * ``dup``        — iterable of (src, dst, seq): delivered twice
    * ``delay``      — iterable of (src, dst, seq, hold): held back until
      ``hold`` further messages pass on the link (reordering)
    * ``partitions`` — iterable of (src, dst, lo, hi): every message with
      ``lo <= seq <= hi`` on the link is dropped (a one-way partition;
      list both directions for a full one)
    """

    def __init__(self, drop=(), dup=(), delay=(), partitions=()):
        self.drop = {(s, d, q) for s, d, q in drop}
        self.dup = {(s, d, q) for s, d, q in dup}
        self.delay = {(s, d, q): hold for s, d, q, hold in delay}
        self.partitions = list(partitions)

    def is_dropped(self, src: str, dst: str, seq: int) -> bool:
        if (src, dst, seq) in self.drop:
            return True
        return any(s == src and d == dst and lo <= seq <= hi
                   for s, d, lo, hi in self.partitions)

    def is_dup(self, src: str, dst: str, seq: int) -> bool:
        return (src, dst, seq) in self.dup

    def delay_of(self, src: str, dst: str, seq: int) -> int:
        return self.delay.get((src, dst, seq), 0)


class FaultTransport:
    """Wraps an `InProcTransport`-shaped transport with a `FaultSchedule`.
    Dropped messages still report success to the sender (network loss is
    silent); counters expose what was injected so tests can assert the
    schedule actually fired."""

    def __init__(self, inner: InProcTransport, schedule: FaultSchedule):
        self.inner = inner
        self.schedule = schedule
        self._seq: dict[tuple[str, str], int] = {}
        self._held: dict[tuple[str, str], list] = {}
        self.dropped = 0
        self.duplicated = 0
        self.delayed = 0

    def register(self, node_id: str) -> None:
        self.inner.register(node_id)

    def kill(self, node_id: str) -> None:
        self.inner.kill(node_id)

    def alive(self, node_id: str) -> bool:
        return self.inner.alive(node_id)

    def send(self, src: str, dst: str, data: bytes) -> bool:
        link = (src, dst)
        seq = self._seq.get(link, 0) + 1
        self._seq[link] = seq
        sched = self.schedule
        ok = True
        if sched.is_dropped(src, dst, seq):
            self.dropped += 1
        elif sched.delay_of(src, dst, seq):
            self._held.setdefault(link, []).append(
                (seq + sched.delay_of(src, dst, seq), data))
            self.delayed += 1
        else:
            ok = self.inner.send(src, dst, data)
            if sched.is_dup(src, dst, seq):
                self.inner.send(src, dst, data)
                self.duplicated += 1
        held = self._held.get(link)
        if held:
            keep = []
            for release, msg in held:
                if release <= seq:
                    self.inner.send(src, dst, msg)
                else:
                    keep.append((release, msg))
            self._held[link] = keep
        return ok

    def heal(self) -> None:
        """Deliver every still-held (delayed) message now."""
        for (src, dst), held in self._held.items():
            for _release, msg in held:
                self.inner.send(src, dst, msg)
            held.clear()

    def poll(self, node_id: str) -> list[tuple[str, bytes]]:
        return self.inner.poll(node_id)


class InProcEndpoint:
    """Per-node view over an (optionally fault-wrapped) transport — the
    interface the replicators speak: ``send(dst, data)``, ``poll()``,
    ``connect(peer, head=...)``."""

    def __init__(self, transport, node_id: str):
        self.transport = transport
        self.node_id = node_id
        transport.register(node_id)

    def connect(self, peer_id: str, addr=None, head: dict | None = None):
        h = {"node": self.node_id}
        h.update(head or {})
        self.send(peer_id, encode_msg(MSG_HELLO, h))

    def send(self, dst: str, data: bytes) -> bool:
        return self.transport.send(self.node_id, dst, data)

    def poll(self) -> list[tuple[str, bytes]]:
        return self.transport.poll(self.node_id)

    def close(self) -> None:
        self.transport.kill(self.node_id)


class SocketEndpoint:
    """Localhost-TCP endpoint with the same surface as `InProcEndpoint`.
    Frames are u32-length-prefixed; the first frame on an inbound
    connection must be a HELLO naming the peer (it is also delivered to
    the application, which uses it to register the peer).  Used by the
    cross-process SIGKILL failover test, where the primary genuinely dies
    mid-ingest."""

    RECV_BYTES = 1 << 16

    def __init__(self, node_id: str, host: str = "127.0.0.1", port: int = 0):
        self.node_id = node_id
        self._listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._listener.bind((host, port))
        self._listener.listen(8)
        self._listener.setblocking(False)
        self._conns: dict[str, socket.socket] = {}
        self._bufs: dict[socket.socket, bytearray] = {}
        self._unnamed: list[socket.socket] = []

    @property
    def addr(self) -> tuple[str, int]:
        return self._listener.getsockname()

    def connect(self, peer_id: str, addr, head: dict | None = None) -> None:
        s = socket.create_connection(addr, timeout=10.0)
        s.settimeout(10.0)
        self._conns[peer_id] = s
        self._bufs[s] = bytearray()
        h = {"node": self.node_id}
        h.update(head or {})
        self._send_frame(s, encode_msg(MSG_HELLO, h))

    def _send_frame(self, s: socket.socket, data: bytes) -> None:
        s.sendall(struct.pack("<I", len(data)) + data)

    def send(self, dst: str, data: bytes) -> bool:
        s = self._conns.get(dst)
        if s is None:
            return False
        try:
            self._send_frame(s, data)
            return True
        except OSError:
            self._drop(dst)
            return False

    def _drop(self, peer_id: str) -> None:
        s = self._conns.pop(peer_id, None)
        if s is not None:
            self._bufs.pop(s, None)
            try:
                s.close()
            except OSError:
                pass

    def _readable(self, s) -> bool:
        r, _, _ = select.select([s], [], [], 0)
        return bool(r)

    def poll(self) -> list[tuple[str, bytes]]:
        while self._readable(self._listener):
            try:
                c, _ = self._listener.accept()
            except OSError:
                break
            c.settimeout(10.0)
            self._unnamed.append(c)
            self._bufs[c] = bytearray()
        out: list[tuple[str, bytes]] = []
        for peer, s in (list(self._conns.items())
                        + [(None, c) for c in list(self._unnamed)]):
            dead = False
            while self._readable(s):
                try:
                    data = s.recv(self.RECV_BYTES)
                except OSError:
                    data = b""
                if not data:
                    dead = True
                    break
                self._bufs[s] += data
            buf = self._bufs.get(s)
            while buf is not None and len(buf) >= 4:
                (ln,) = struct.unpack_from("<I", buf)
                if len(buf) < 4 + ln:
                    break
                frame = bytes(buf[4:4 + ln])
                del buf[:4 + ln]
                if peer is None:
                    # first frame names the connection
                    try:
                        kind, head, _ = decode_msg(frame)
                    except CorruptError:
                        dead = True
                        break
                    if kind != MSG_HELLO:
                        dead = True
                        break
                    peer = head["node"]
                    self._unnamed.remove(s)
                    self._conns[peer] = s
                out.append((peer, frame))
            if dead:
                if peer is not None:
                    self._drop(peer)
                elif s in self._unnamed:
                    self._unnamed.remove(s)
                    self._bufs.pop(s, None)
                    try:
                        s.close()
                    except OSError:
                        pass
        return out

    def peers(self) -> list[str]:
        return list(self._conns)

    def close(self) -> None:
        for peer in list(self._conns):
            self._drop(peer)
        try:
            self._listener.close()
        except OSError:
            pass


# ----------------------------------------------------------------- primary
class _Peer:
    __slots__ = ("node_id", "durable_lsn", "sent_upto", "last_seen")

    def __init__(self, node_id: str, lsn: int = 0):
        self.node_id = node_id
        self.durable_lsn = lsn
        self.sent_upto = lsn
        self.last_seen = 0.0


class ReplicatedWal(WalWriter):
    """A `WalWriter` whose records also ship to replicas and whose
    ``sync()`` is the *quorum* group-commit barrier: local fsync first,
    then block until the configured quorum of members (this primary
    included) has fsynced through the last appended LSN.  Because the
    serve engine's ingest ack already sits behind ``wal.sync()``, swapping
    this writer in makes every ack quorum-durable with no engine change."""

    def __init__(self, dirpath: str, primary: "PrimaryReplicator",
                 io: OsIO | None = None, segment_bytes: int = 4 << 20,
                 epoch: int | None = None):
        super().__init__(dirpath, io=io, segment_bytes=segment_bytes,
                         epoch=epoch)
        self._primary = primary

    def append(self, rtype: int, payload: bytes = b"",
               fsync: bool = True) -> int:
        self._primary.check_fenced()
        lsn = super().append(rtype, payload, fsync=False)
        self._primary.ship(rtype, lsn, payload)
        if fsync:
            self.sync()
        return lsn

    def sync(self) -> None:
        super().sync()
        self._primary.await_quorum(self.next_lsn - 1)


class PrimaryReplicator:
    """The primary's half of the protocol: per-peer shipping state, the
    quorum wait, heartbeats, catch-up/retransmission, and chunked
    checkpoint streaming for bootstrapping replicas.

    ``quorum`` counts the primary itself: 1 = local durability only
    (replicas are asynchronous), 2 = at least one replica must fsync
    before an ack, etc.  ``peer_pump`` is an optional callable invoked
    once per pump — the in-process cluster points it at the replicas'
    ``pump()`` so a quorum wait makes progress inside one process."""

    def __init__(self, index, root: str, endpoint, node_id: str = "primary",
                 quorum: int = 1, io: OsIO | None = None,
                 heartbeat_s: float = 0.05, now=None, idle_s: float = 0.0,
                 max_pumps: int = 200_000, stall_pumps: int = 64,
                 tail_cap: int = 1024, peer_pump=None):
        self.index = index
        self.root = root
        self.endpoint = endpoint
        self.node_id = node_id
        self.quorum = int(quorum)
        self.io = io or OsIO()
        self.heartbeat_s = heartbeat_s
        self.idle_s = idle_s
        self.max_pumps = max_pumps
        self.stall_pumps = stall_pumps
        self.tail_cap = tail_cap
        self.peer_pump = peer_pump
        self._now = now or time.monotonic
        self.epoch = int(getattr(index, "_epoch", 0))
        self.fenced = False
        self.peers: dict[str, _Peer] = {}
        self._tail: OrderedDict[int, tuple[int, bytes]] = OrderedDict()
        self._last_lsn = int(getattr(index, "_applied_lsn", 0))
        # the LSN at which this primary's epoch began: records at or below
        # it are shared history (every replica's log is a prefix of the
        # promoted max-durable log), records above it belong to this term.
        # A HELLO from a *lower* epoch claiming an LSN above this base may
        # be a deposed primary's diverged unacked suffix.
        self.epoch_base = self._last_lsn
        self._last_hb = float("-inf")
        self._awaiting = False

    # ------------------------------------------------------------ lifecycle
    def attach(self, segment_bytes: int = 4 << 20) -> ReplicatedWal:
        """Replace the index's plain `WalWriter` with a `ReplicatedWal`
        over the same log directory.  Call after ``open_durable``."""
        old = getattr(self.index, "_wal", None)
        if old is not None:
            old.close()
        rw = ReplicatedWal(_recovery.wal_dir(self.root), self, io=self.io,
                           segment_bytes=segment_bytes, epoch=self.epoch)
        self.index._wal = rw
        self._last_lsn = rw.next_lsn - 1
        self.epoch_base = self._last_lsn  # no new-term records appended yet
        return rw

    def check_fenced(self) -> None:
        if self.fenced:
            raise StaleEpochError(
                f"primary {self.node_id} (epoch {self.epoch}) is fenced by "
                f"a newer epoch: refusing to append"
            )

    def _fence(self, newer_epoch: int) -> None:
        if newer_epoch > self.epoch:
            self.fenced = True

    # ------------------------------------------------------------- shipping
    def ship(self, rtype: int, lsn: int, payload: bytes) -> None:
        """Ship one just-appended record to every caught-up peer (lagging
        peers are served by ``_catch_up`` so their stream stays ordered)."""
        self.check_fenced()
        self._last_lsn = lsn
        self._tail[lsn] = (rtype, payload)
        while len(self._tail) > self.tail_cap:
            self._tail.popitem(last=False)
        msg = encode_msg(MSG_APPEND, {
            "epoch": self.epoch, "lsn": lsn, "rtype": rtype,
            "commit": lsn,
        }, payload)
        for p in self.peers.values():
            if p.sent_upto == lsn - 1 and self.endpoint.send(p.node_id, msg):
                p.sent_upto = lsn

    def acked_count(self, lsn: int) -> int:
        """Members (primary included) known to have fsynced through
        ``lsn``."""
        return 1 + sum(1 for p in self.peers.values()
                       if p.durable_lsn >= lsn)

    def await_quorum(self, lsn: int) -> None:
        """Block (pumping the transport) until ``quorum`` members have
        fsynced through ``lsn``.  The local fsync already happened
        (`ReplicatedWal.sync` runs it first), so the ack that follows this
        barrier is quorum-durable.  Raises `QuorumTimeoutError` after the
        pump budget — a refusal, never a false ack."""
        self.check_fenced()
        if self.quorum <= 1 or lsn <= 0 or self._awaiting:
            # re-entrant waits (an auto-compaction record logged while
            # serving a bootstrap inside an outer wait) collapse into the
            # outer barrier, which always waits for the highest ack
            return
        self._awaiting = True
        try:
            pumps = 0
            while self.acked_count(lsn) < self.quorum:
                progressed = self.pump()
                self.check_fenced()
                pumps += 1
                if not progressed and pumps % self.stall_pumps == 0:
                    self._retransmit(lsn)
                if pumps > self.max_pumps:
                    raise QuorumTimeoutError(
                        f"quorum {self.quorum} not reached for LSN {lsn} "
                        f"({self.acked_count(lsn)} acked) within "
                        f"{self.max_pumps} pumps"
                    )
        finally:
            self._awaiting = False

    def _retransmit(self, lsn: int) -> None:
        for p in self.peers.values():
            if p.durable_lsn < lsn:
                p.sent_upto = p.durable_lsn
                self._catch_up(p)

    # ------------------------------------------------------------- pumping
    def pump(self, now: float | None = None) -> bool:
        """Deliver inbound messages and heartbeat on cadence.  Returns
        True when at least one message was processed."""
        if self.peer_pump is not None:
            self.peer_pump()
        now = self._now() if now is None else now
        msgs = self.endpoint.poll()
        for src, data in msgs:
            self._on_msg(src, data, now)
        self.maybe_heartbeat(now)
        if not msgs and self.idle_s:
            time.sleep(self.idle_s)
        return bool(msgs)

    def maybe_heartbeat(self, now: float) -> None:
        if now - self._last_hb < self.heartbeat_s:
            return
        self._last_hb = now
        msg = encode_msg(MSG_HEARTBEAT,
                         {"epoch": self.epoch, "lsn": self._last_lsn})
        for p in self.peers.values():
            self.endpoint.send(p.node_id, msg)

    def _peer(self, node_id: str) -> _Peer:
        p = self.peers.get(node_id)
        if p is None:
            p = self.peers[node_id] = _Peer(node_id)
        return p

    def _on_msg(self, src: str, data: bytes, now: float) -> None:
        try:
            kind, head, payload = decode_msg(data)
        except CorruptError:
            return  # corrupt frame: drop; retransmission heals
        if kind == MSG_HELLO:
            p = self._peer(src)
            p.last_seen = now
            p.durable_lsn = int(head.get("lsn", 0))
            p.sent_upto = p.durable_lsn
            hello_epoch = int(head.get("epoch", 0))
            if hello_epoch > self.epoch:
                self._fence(hello_epoch)
                return
            diverged = p.durable_lsn > self._last_lsn or (
                hello_epoch < self.epoch
                and p.durable_lsn > self.epoch_base
            )
            # answer every HELLO with an immediate heartbeat so the peer
            # learns the commit LSN (and that we are alive) even when it
            # is already caught up and no record will be shipped
            self.endpoint.send(src, encode_msg(
                MSG_HEARTBEAT, {"epoch": self.epoch, "lsn": self._last_lsn}))
            if head.get("boot"):
                self._serve_bootstrap(src, head)
            elif diverged:
                # a peer with records past our tail, or from an older term
                # with records past our promotion point, may hold a
                # diverged unacked suffix — e.g. the deposed primary
                # rejoining.  Reconciliation is a full re-bootstrap: it
                # discards its local state and streams ours (the simple,
                # always-safe Raft-truncation analogue).  A lower-epoch
                # peer at or below the base is just lagging shared history
                # and catches up normally.
                self._serve_bootstrap(src, {"have": {}})
            else:
                self._catch_up(p)
        elif kind == MSG_ACK:
            ack_epoch = int(head["epoch"])
            if ack_epoch > self.epoch:
                self._fence(ack_epoch)
                return
            if ack_epoch < self.epoch:
                return  # stale-term ack: ignore
            p = self._peer(src)
            p.last_seen = now
            if head["lsn"] > p.durable_lsn:
                p.durable_lsn = int(head["lsn"])
            if p.sent_upto < p.durable_lsn:
                p.sent_upto = p.durable_lsn
            self._catch_up(p)
        elif kind == MSG_NACK:
            p = self._peer(src)
            p.last_seen = now
            p.sent_upto = max(int(head["expect"]) - 1, 0)
            self._catch_up(p)
        elif kind == MSG_FENCED:
            self._fence(int(head["epoch"]))
        elif kind == MSG_BOOT_REQ:
            self._peer(src).last_seen = now
            self._serve_bootstrap(src, head)

    # ------------------------------------------------- catch-up / bootstrap
    def _records_from(self, lsn: int):
        """Records >= ``lsn`` from the in-memory tail or the on-disk log;
        None when the log no longer reaches back that far (pruned) and the
        peer must bootstrap from a checkpoint instead."""
        if self._tail and lsn >= next(iter(self._tail)):
            return [(l, t, p) for l, (t, p) in self._tail.items()
                    if l >= lsn]
        recs = [(l, t, p)
                for l, t, p in _read_log(_recovery.wal_dir(self.root),
                                         io=self.io, truncate_torn=False)
                if l >= lsn]
        if recs and recs[0][0] != lsn:
            return None
        if not recs and lsn <= self._last_lsn:
            return None
        return recs

    def _catch_up(self, peer: _Peer) -> None:
        if peer.sent_upto > peer.durable_lsn:
            return  # records in flight; a NACK or stall will reset
        if peer.durable_lsn >= self._last_lsn:
            return
        recs = self._records_from(peer.durable_lsn + 1)
        if recs is None:
            self._serve_bootstrap(peer.node_id, {"have": {}})
            return
        for lsn, rtype, payload in recs:
            msg = encode_msg(MSG_APPEND, {
                "epoch": self.epoch, "lsn": lsn, "rtype": rtype,
                "commit": self._last_lsn,
            }, payload)
            if not self.endpoint.send(peer.node_id, msg):
                return
            peer.sent_upto = lsn

    def _serve_bootstrap(self, dst: str, head: dict) -> None:
        """Stream the newest FULL checkpoint to ``dst``: manifest, then
        every chunk the peer does not already hold (``head['have']`` maps
        section name -> chunk indices that validated on its disk — the
        resume path), then DONE, then the WAL suffix past the checkpoint."""
        # whatever the peer claimed to hold is void once it re-bootstraps
        # (its history may diverge) — it must not count toward any quorum
        # until it acks records from *this* stream
        p = self._peer(dst)
        p.durable_lsn = 0
        p.sent_upto = 0
        ckpts = _ckpt.list_checkpoints(self.root)
        man = None
        if ckpts:
            try:
                man = read_manifest(ckpts[-1][1])
            except CorruptError:
                man = None
        if man is None or man["kind"] != "full":
            _ckpt.save(self.index, self.root, io=self.io, incremental=False)
            ckpts = _ckpt.list_checkpoints(self.root)
            man = read_manifest(ckpts[-1][1])
        path = dict(ckpts)[man["seq"]]
        have = head.get("have") or {}
        self.endpoint.send(dst, encode_msg(
            MSG_CKPT_META, {"manifest": man, "epoch": self.epoch}))
        for name in sorted(man["sections"]):
            entry = man["sections"][name]
            with open(os.path.join(path, entry["file"]), "rb") as f:
                data = f.read()
            cb = int(entry.get("chunk_bytes", STREAM_CHUNK_BYTES))
            crcs = entry.get("chunk_crcs") or chunk_crcs(data, cb)
            got = set(have.get(name, ()))
            for ci, c in enumerate(crcs):
                if ci in got:
                    continue
                off = ci * cb
                ok = self.endpoint.send(dst, encode_msg(
                    MSG_CKPT_CHUNK,
                    {"section": name, "ci": ci, "off": off, "crc": c},
                    data[off:off + cb]))
                if not ok:
                    return
        self.endpoint.send(dst, encode_msg(MSG_CKPT_DONE, {
            "seq": man["seq"], "lsn": man["meta"]["lsn"],
            "epoch": self.epoch,
        }))
        p = self._peer(dst)
        lsn = int(man["meta"]["lsn"])
        p.sent_upto = max(p.sent_upto, lsn)
        recs = self._records_from(lsn + 1) or []
        for rlsn, rtype, payload in recs:
            msg = encode_msg(MSG_APPEND, {
                "epoch": self.epoch, "lsn": rlsn, "rtype": rtype,
                "commit": self._last_lsn,
            }, payload)
            if not self.endpoint.send(dst, msg):
                return
            p.sent_upto = rlsn

    # ---------------------------------------------------------------- state
    def status(self) -> dict:
        return {
            "node": self.node_id,
            "role": "primary",
            "epoch": self.epoch,
            "fenced": self.fenced,
            "lsn": self._last_lsn,
            "quorum": self.quorum,
            "peers": {
                p.node_id: {"durable_lsn": p.durable_lsn,
                            "lag": max(0, self._last_lsn - p.durable_lsn)}
                for p in self.peers.values()
            },
        }


# ----------------------------------------------------------------- replica
class ReplicaReplicator:
    """The replica's half: append each shipped record to its own WAL at
    the same LSN, fsync, apply under ``_wal_replaying``, cumulative-ACK.
    Buffers out-of-order arrivals, NACKs gaps, refuses stale epochs
    (strictly — ``epoch < self.epoch`` is fenced, ``>`` adopts), and
    bootstraps by streaming the primary's checkpoint with chunk-level
    resume."""

    def __init__(self, root: str, endpoint, node_id: str,
                 primary_id: str | None = None, io: OsIO | None = None,
                 now=None, segment_bytes: int = 4 << 20,
                 heartbeat_timeout_s: float = 0.5, nack_every: int = 8,
                 oo_cap: int = 256):
        self.root = root
        self.endpoint = endpoint
        self.node_id = node_id
        self.primary_id = primary_id
        self.io = io or OsIO()
        self._now = now or time.monotonic
        self.segment_bytes = segment_bytes
        self.heartbeat_timeout_s = heartbeat_timeout_s
        self.nack_every = nack_every
        self.oo_cap = oo_cap
        self.index = None
        self.wal: WalWriter | None = None
        self.epoch = 0
        self.primary_lsn = 0  # newest commit LSN heard from the primary
        self.last_heard: float | None = None
        self._oo: dict[int, tuple[int, bytes]] = {}
        self._boot: dict | None = None
        self._msgs_since_nack = 0
        self._hello_t = float("-inf")

    # ------------------------------------------------------------ lifecycle
    def start(self) -> None:
        """Open local durable state if present (recover + attach WAL),
        else request a streamed bootstrap.  A half-finished bootstrap on
        disk resumes: only chunks whose bytes fail their CRC re-ship."""
        if _recovery.is_durable_dir(self.root):
            self.index = _recovery.open_durable(
                self.root, io=self.io, segment_bytes=self.segment_bytes)
            self.wal = self.index._wal
            self.epoch = int(self.index._epoch)
            self._hello()
            return
        resumed = self._resume_boot_from_disk()
        if self.primary_id is not None:
            if resumed:
                self._request_boot()
            else:
                self._hello(boot=True)

    def _hello(self, boot: bool = False) -> None:
        if self.primary_id is None:
            return
        head = {"node": self.node_id, "lsn": self.durable_lsn,
                "epoch": self.epoch}
        if boot:
            head["boot"] = True
        self._hello_t = self._now()
        self.endpoint.send(self.primary_id, encode_msg(MSG_HELLO, head))

    @property
    def durable_lsn(self) -> int:
        """Highest LSN in the local log (== fsynced-through at every ack
        boundary: `_drain` syncs before acking)."""
        if self.wal is not None:
            return self.wal.next_lsn - 1
        return 0

    def lag(self) -> int:
        """How far the local durable LSN trails the primary's commit."""
        return max(0, self.primary_lsn - self.durable_lsn)

    def caught_up(self) -> bool:
        # requires at least one contact: before the first heartbeat the
        # primary's commit LSN is unknown and lag() would read as zero
        return (self.index is not None and self.last_heard is not None
                and self.lag() == 0)

    def primary_alive(self, now: float | None = None) -> bool:
        """False once the heartbeat timeout elapsed with no traffic from
        the primary — the cluster's failover trigger."""
        if self.last_heard is None:
            return True  # never heard: grace until first contact
        now = self._now() if now is None else now
        return (now - self.last_heard) < self.heartbeat_timeout_s

    # -------------------------------------------------------------- pumping
    def pump(self, now: float | None = None) -> int:
        now = self._now() if now is None else now
        msgs = self.endpoint.poll()
        for src, data in msgs:
            try:
                kind, head, payload = decode_msg(data)
            except CorruptError:
                continue  # corrupt frame: drop; retransmission heals
            self._on_msg(src, kind, head, payload, now)
        if (self.last_heard is None and self.primary_id is not None
                and now - self._hello_t >= self.heartbeat_timeout_s):
            # the initial HELLO may have been lost on the wire — retry on
            # the heartbeat-timeout cadence until the primary answers
            if self._boot is not None:
                self._hello_t = now
                self._request_boot()
            else:
                self._hello(boot=self.index is None)
        return len(msgs)

    def _on_msg(self, src: str, kind: int, head: dict, payload: bytes,
                now: float) -> None:
        if kind == MSG_HELLO:
            self.primary_id = src
            self.last_heard = now
            if self.index is not None:
                self._hello()
            elif self._boot is None:
                self._hello(boot=True)
            else:
                self._request_boot()
        elif kind == MSG_APPEND:
            self._on_append(src, head, payload, now)
        elif kind == MSG_HEARTBEAT:
            self._on_heartbeat(src, head, now)
        elif kind == MSG_CKPT_META:
            self.last_heard = now
            self._on_ckpt_meta(head)
        elif kind == MSG_CKPT_CHUNK:
            self.last_heard = now
            self._on_ckpt_chunk(head, payload)
        elif kind == MSG_CKPT_DONE:
            self.last_heard = now
            self._on_ckpt_done(src, head)

    def _check_epoch(self, src: str, msg_epoch: int) -> bool:
        """Strict fencing: a lower epoch is refused (FENCED reply), a
        higher one adopted (the sender is a newer primary)."""
        if msg_epoch < self.epoch:
            self.endpoint.send(src, encode_msg(
                MSG_FENCED, {"epoch": self.epoch}))
            return False
        if msg_epoch > self.epoch:
            self._adopt_epoch(msg_epoch)
        return True

    def _adopt_epoch(self, msg_epoch: int) -> None:
        self.epoch = msg_epoch
        if self.wal is not None:
            self.wal.set_epoch(msg_epoch)
        if self.index is not None:
            self.index._epoch = msg_epoch

    def _on_append(self, src: str, head: dict, payload: bytes,
                   now: float) -> None:
        if not self._check_epoch(src, int(head["epoch"])):
            return
        self.primary_id = src
        self.last_heard = now
        self.primary_lsn = max(self.primary_lsn, int(head.get("commit", 0)),
                               int(head["lsn"]))
        if self.index is None or self.wal is None:
            return  # bootstrapping: the suffix re-ships after finalize
        lsn = int(head["lsn"])
        if lsn <= self.durable_lsn:
            self._send_ack(src)  # duplicate: idempotent cumulative re-ack
            return
        if lsn == self.durable_lsn + 1 or len(self._oo) < self.oo_cap:
            self._oo[lsn] = (int(head["rtype"]), payload)
        self._drain(src)
        if self.durable_lsn + 1 not in self._oo and lsn > self.durable_lsn + 1:
            self._maybe_nack(src)

    def _drain(self, src: str) -> None:
        """Append every consecutive buffered record (one group-commit
        fsync), apply them under the replay guard, then cumulative-ACK —
        log -> fsync -> apply -> ack, the same discipline as recovery."""
        staged: list[tuple[int, int, bytes]] = []
        nxt = self.durable_lsn + 1
        while nxt in self._oo:
            rtype, payload = self._oo.pop(nxt)
            got = self.wal.append(rtype, payload, fsync=False)
            if got != nxt:
                raise WalCorruptError(
                    f"replica log continuity broken: appended at {got}, "
                    f"expected {nxt}"
                )
            staged.append((nxt, rtype, payload))
            nxt += 1
        if staged:
            self.wal.sync()
            idx = self.index
            idx._wal_replaying = True
            try:
                for lsn, rtype, payload in staged:
                    apply_record(idx, rtype, payload)
                    idx._applied_lsn = lsn
            finally:
                idx._wal_replaying = False
            self._send_ack(src)

    def _send_ack(self, dst: str) -> None:
        self.endpoint.send(dst, encode_msg(
            MSG_ACK, {"epoch": self.epoch, "lsn": self.durable_lsn}))

    def _maybe_nack(self, src: str) -> None:
        self._msgs_since_nack += 1
        if self._msgs_since_nack >= self.nack_every:
            self._msgs_since_nack = 0
            self.endpoint.send(src, encode_msg(
                MSG_NACK, {"expect": self.durable_lsn + 1}))

    def _on_heartbeat(self, src: str, head: dict, now: float) -> None:
        if not self._check_epoch(src, int(head["epoch"])):
            return
        self.primary_id = src
        self.last_heard = now
        self.primary_lsn = max(self.primary_lsn, int(head["lsn"]))
        if self.index is None:
            # a lost CKPT_META/DONE would otherwise strand the bootstrap;
            # the primary's heartbeat doubles as the retry tick
            self._msgs_since_nack += 1
            if self._msgs_since_nack >= self.nack_every:
                self._msgs_since_nack = 0
                if self._boot is None:
                    self._hello(boot=True)
                else:
                    self._request_boot()
        elif self.lag() > 0:
            self._maybe_nack(src)

    # ------------------------------------------------------------ bootstrap
    def _boot_tmp(self) -> str:
        return os.path.join(self.root, "bootstrap.tmp")

    def _request_boot(self) -> None:
        if self.primary_id is None:
            return
        have = {}
        if self._boot is not None:
            have = {name: sorted(got)
                    for name, got in self._boot["got"].items()}
        self.endpoint.send(self.primary_id, encode_msg(
            MSG_BOOT_REQ, {"have": have}))

    def _resume_boot_from_disk(self) -> bool:
        """Rescan a half-finished bootstrap left by a crash: reload the
        manifest from ``MANIFEST.part`` and CRC-check every chunk already
        on disk, so the re-request ships only what is missing."""
        tmp = self._boot_tmp()
        part = os.path.join(tmp, BOOT_PART_NAME)
        if not os.path.exists(part):
            return False
        try:
            with open(part, "rb") as f:
                man = json.loads(f.read())
        except (OSError, ValueError):
            return False
        self._boot = {"man": man, "got": {}, "tmp": tmp}
        for name, entry in man["sections"].items():
            fpath = os.path.join(tmp, entry["file"])
            try:
                with open(fpath, "rb") as f:
                    data = f.read()
            except OSError:
                continue
            cb = int(entry.get("chunk_bytes", STREAM_CHUNK_BYTES))
            crcs = entry.get("chunk_crcs") or []
            got = set()
            for ci, c in enumerate(crcs):
                if crc32(data[ci * cb:ci * cb + cb]) == c:
                    got.add(ci)
            if got:
                self._boot["got"][name] = got
        return True

    def _on_ckpt_meta(self, head: dict) -> None:
        man = head["manifest"]
        if self.index is not None:
            # the primary decided our local history diverged (stale epoch
            # or an unacked suffix past its tail): discard local state and
            # take the full stream — the Raft-truncation analogue
            if self.wal is not None:
                self.wal.close()
            self.wal = None
            self.index = None
            self._oo.clear()
        if self._boot is not None and self._boot["man"]["seq"] == man["seq"]:
            return  # resuming the same checkpoint: keep validated chunks
        tmp = self._boot_tmp()
        self.io.remove(tmp)
        self.io.mkdir(tmp)
        f = self.io.create(os.path.join(tmp, BOOT_PART_NAME))
        try:
            self.io.write(f, json.dumps(man, sort_keys=True).encode())
            self.io.fsync(f)
        finally:
            self.io.close(f)
        self._boot = {"man": man, "got": {}, "tmp": tmp}

    def _on_ckpt_chunk(self, head: dict, payload: bytes) -> None:
        if self._boot is None:
            return
        man = self._boot["man"]
        name = head["section"]
        entry = man["sections"].get(name)
        if entry is None or crc32(payload) != head["crc"]:
            return  # unknown/corrupt chunk: the DONE check re-requests it
        fpath = os.path.join(self._boot["tmp"], entry["file"])
        if not os.path.exists(fpath):
            f = self.io.create(fpath)
            try:
                self.io.write(f, b"\x00" * int(entry["nbytes"]))
            finally:
                self.io.close(f)
        with open(fpath, "r+b") as f:
            f.seek(int(head["off"]))
            f.write(payload)
            f.flush()
            os.fsync(f.fileno())
        self._boot["got"].setdefault(name, set()).add(int(head["ci"]))

    def _boot_complete(self) -> bool:
        man = self._boot["man"]
        for name, entry in man["sections"].items():
            crcs = entry.get("chunk_crcs") or []
            got = self._boot["got"].get(name, set())
            if len(got) < len(crcs):
                return False
            fpath = os.path.join(self._boot["tmp"], entry["file"])
            try:
                with open(fpath, "rb") as f:
                    data = f.read()
            except OSError:
                return False
            if len(data) != entry["nbytes"] or crc32(data) != entry["crc32"]:
                return False
        return True

    def _on_ckpt_done(self, src: str, head: dict) -> None:
        if self._boot is None:
            return
        if not self._boot_complete():
            self._request_boot()  # only the missing chunks re-ship
            return
        man = self._boot["man"]
        tmp = self._boot["tmp"]
        # finalize: write the real manifest, fsync, atomic-rename into the
        # checkpoint directory — from here on this is a normal durable dir
        f = self.io.create(os.path.join(tmp, MANIFEST_NAME))
        try:
            self.io.write(f, json.dumps(man, sort_keys=True,
                                        indent=1).encode())
            self.io.fsync(f)
        finally:
            self.io.close(f)
        self.io.remove(os.path.join(tmp, BOOT_PART_NAME))
        self.io.fsync_dir(tmp)
        # discard any pre-existing local history BEFORE the new checkpoint
        # becomes visible: stale checkpoints (possibly with a higher seq)
        # and a diverged WAL must never outrank the streamed state.  A
        # crash in this window leaves no finalized checkpoint plus
        # ``bootstrap.tmp`` — exactly the resume path ``start()`` takes.
        for _seq, old in _ckpt.list_checkpoints(self.root):
            self.io.remove(old)
        wdir = _recovery.wal_dir(self.root)
        if os.path.exists(wdir):
            self.io.remove(wdir)
        ckdir = _ckpt.checkpoint_dir(self.root)
        self.io.mkdir(ckdir)
        final = os.path.join(ckdir, f"{_ckpt.CKPT_PREFIX}{man['seq']:08d}")
        self.io.replace(tmp, final)
        self.io.fsync_dir(ckdir)
        self._boot = None
        self.index = _ckpt.materialize(_ckpt.load_state(self.root))
        done_epoch = int(head.get("epoch", self.index._epoch))
        if done_epoch > self.epoch:
            self.epoch = done_epoch
        if self.epoch > self.index._epoch:
            self.index._epoch = self.epoch
        self.epoch = int(self.index._epoch)
        self.wal = WalWriter(
            _recovery.wal_dir(self.root), io=self.io,
            segment_bytes=self.segment_bytes, epoch=self.epoch,
            start_lsn=self.index._applied_lsn + 1)
        self.index._wal = self.wal
        self._send_ack(src)

    # ------------------------------------------------------------ promotion
    def promote(self, new_epoch: int | None = None) -> int:
        """Promote this replica: adopt an epoch strictly above everything
        it has observed and stamp it into the log (rotate) *before* any
        new-term record — the on-disk fence that refuses the old primary.
        Returns the new epoch."""
        if self.index is None or self.wal is None:
            raise RuntimeError(f"{self.node_id}: cannot promote before "
                               f"bootstrap completes")
        e = self.epoch + 1 if new_epoch is None else int(new_epoch)
        if not e > self.epoch:
            raise StaleEpochError(
                f"promotion epoch {e} must exceed observed epoch "
                f"{self.epoch}"
            )
        self.wal.set_epoch(e)
        self.epoch = e
        self.index._epoch = e
        return e

    def status(self) -> dict:
        return {
            "node": self.node_id,
            "role": "replica",
            "epoch": self.epoch,
            "lsn": self.durable_lsn,
            "primary_lsn": self.primary_lsn,
            "lag": self.lag(),
            "bootstrapping": self._boot is not None,
            "caught_up": self.caught_up(),
        }
